//! Workload generation.
//!
//! * The paper's exact prompt sets (§4.3): 10 cache prompts + 6 test
//!   prompts, loaded from `data/*.csv` when present, with the same
//!   built-in constants as fallback (they're written by the artifact
//!   build from the same source of truth).
//! * Synthetic overlap workloads with a controlled k/m ratio for the §5.5
//!   sweep and the ablations.

use std::path::Path;

use crate::util::csv;
use crate::util::rng::Rng;

/// A cache-prompts + test-prompts pair.
#[derive(Debug, Clone)]
pub struct Workload {
    pub cache_prompts: Vec<String>,
    pub test_prompts: Vec<String>,
}

const PAPER_CACHE: [&str; 10] = [
    "Explain machine learning in simple terms.",
    "What is the capital of France?",
    "How do airplanes fly?",
    "What is deep learning?",
    "Explain gravity in simple terms.",
    "How do boats float?",
    "What is the capital of Japan?",
    "Explain photosynthesis in simple terms.",
    "How do rockets launch?",
    "What is a cache?",
];

const PAPER_TEST: [&str; 6] = [
    "Explain machine learning in simple terms. Give an example application.",
    "What is the capital of France? Also mention a nearby tourist destination.",
    "How do airplanes fly? Keep the answer short.",
    "What is deep learning? Compare it with machine learning.",
    "Explain gravity in simple terms. Why does the moon stay in orbit?",
    "What is a cache? Why do browsers use one?",
];

fn load_or(path: &Path, fallback: &[&str]) -> Vec<String> {
    csv::read_single_column(path)
        .unwrap_or_else(|_| fallback.iter().map(|s| s.to_string()).collect())
}

/// The paper's 10 cache prompts (data/cache_prompts.csv when available).
pub fn paper_cache_prompts(data_dir: &Path) -> Vec<String> {
    load_or(&data_dir.join("cache_prompts.csv"), &PAPER_CACHE)
}

/// The paper's 6 test prompts (data/test_prompts.csv when available).
pub fn paper_test_prompts(data_dir: &Path) -> Vec<String> {
    load_or(&data_dir.join("test_prompts.csv"), &PAPER_TEST)
}

/// Parameters for a synthetic overlap workload.
#[derive(Debug, Clone, Copy)]
pub struct OverlapSpec {
    /// Number of (cache, test) prompt pairs.
    pub pairs: usize,
    /// Words in the shared prefix (≈ reuse depth k in tokens).
    pub prefix_words: usize,
    /// Extra words appended to the test prompt (m - k).
    pub suffix_words: usize,
    /// Fraction of test prompts that should NOT match any cache prompt.
    pub miss_rate: f64,
    pub seed: u64,
}

const WORDS: [&str; 32] = [
    "signal", "engine", "garden", "window", "planet", "cache", "memory",
    "token", "river", "mountain", "bridge", "circuit", "market", "forest",
    "needle", "harbor", "crystal", "lantern", "meadow", "rocket", "anchor",
    "compass", "granite", "whistle", "violet", "thunder", "saddle", "ribbon",
    "copper", "marble", "falcon", "ember",
];

fn sentence(rng: &mut Rng, words: usize) -> String {
    (0..words)
        .map(|_| *rng.choice(&WORDS))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Build a workload where each test prompt extends its cache prompt by
/// `suffix_words` (hit) or is freshly random (miss).
pub fn overlap_workload(spec: OverlapSpec) -> Workload {
    let mut rng = Rng::new(spec.seed);
    let mut cache_prompts = Vec::with_capacity(spec.pairs);
    let mut test_prompts = Vec::with_capacity(spec.pairs);
    for i in 0..spec.pairs {
        let prefix = format!("q{i} {}", sentence(&mut rng, spec.prefix_words));
        cache_prompts.push(prefix.clone());
        if rng.chance(spec.miss_rate) {
            test_prompts.push(format!("z{i} {}", sentence(&mut rng,
                spec.prefix_words + spec.suffix_words)));
        } else {
            test_prompts.push(format!("{prefix} {}", sentence(&mut rng, spec.suffix_words)));
        }
    }
    Workload {
        cache_prompts,
        test_prompts,
    }
}

/// Multi-turn user messages for the session/e2e demo.
pub fn session_workload(turns: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    let questions = [
        "What is the capital of France?",
        "How do airplanes fly?",
        "Explain machine learning in simple terms.",
        "What is a cache?",
        "How do boats float?",
        "Explain gravity in simple terms.",
    ];
    (0..turns).map(|_| rng.choice(&questions).to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sets_have_paper_sizes() {
        let dir = Path::new("definitely-not-a-dir");
        assert_eq!(paper_cache_prompts(dir).len(), 10);
        assert_eq!(paper_test_prompts(dir).len(), 6);
    }

    #[test]
    fn every_paper_test_prompt_extends_a_cache_prompt() {
        let dir = Path::new("definitely-not-a-dir");
        let cache = paper_cache_prompts(dir);
        for t in paper_test_prompts(dir) {
            assert!(
                cache.iter().any(|c| t.starts_with(c.as_str()) && t.len() > c.len()),
                "{t}"
            );
        }
    }

    #[test]
    fn overlap_workload_hits_share_prefix() {
        let w = overlap_workload(OverlapSpec {
            pairs: 20,
            prefix_words: 8,
            suffix_words: 4,
            miss_rate: 0.0,
            seed: 3,
        });
        for (c, t) in w.cache_prompts.iter().zip(&w.test_prompts) {
            assert!(t.starts_with(c.as_str()));
            assert!(t.len() > c.len());
        }
    }

    #[test]
    fn overlap_workload_misses_diverge() {
        let w = overlap_workload(OverlapSpec {
            pairs: 30,
            prefix_words: 6,
            suffix_words: 3,
            miss_rate: 1.0,
            seed: 4,
        });
        for (c, t) in w.cache_prompts.iter().zip(&w.test_prompts) {
            assert!(!t.starts_with(c.as_str()));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = OverlapSpec {
            pairs: 5,
            prefix_words: 5,
            suffix_words: 2,
            miss_rate: 0.5,
            seed: 9,
        };
        let a = overlap_workload(spec);
        let b = overlap_workload(spec);
        assert_eq!(a.test_prompts, b.test_prompts);
    }
}
