//! Benchmark support: workload generation, the baseline-vs-recycled
//! evaluation harness, and table formatting. The `benches/` binaries are
//! thin drivers over this module so the same code also backs the
//! `paper_eval` example and the integration tests.

mod eval;
mod tables;
mod workload;

pub use eval::{config_or_fallback, eval_recycler, run_comparison,
               tokenizer_or_fallback, ComparisonReport, EvalOptions};
pub use tables::{format_row_series, format_table, Table};
pub use workload::{multi_tenant_trace, overlap_workload, paper_cache_prompts,
                   paper_test_prompts, session_workload, OverlapSpec,
                   TraceRequest, TraceSpec, Workload};
