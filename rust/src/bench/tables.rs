//! Plain-text table/series formatting for the bench binaries (the repo's
//! stand-in for the paper's matplotlib figures: each figure is regenerated
//! as a printed series plus a CSV).

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render column-aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Render CSV (for results/ files).
    pub fn to_csv(&self) -> String {
        let mut all = vec![self.header.clone()];
        all.extend(self.rows.iter().cloned());
        crate::util::csv::to_string(&all)
    }
}

/// Format a `(x, y)` series the way the figures are reported in
/// EXPERIMENTS.md: one `x<TAB>y` line each.
pub fn format_row_series(name: &str, series: &[(f64, f64)]) -> String {
    let mut out = format!("# {name}\n");
    for (x, y) in series {
        out.push_str(&format!("{x:.4}\t{y:.4}\n"));
    }
    out
}

/// Two-column key/value table (the paper's §5.1 summary).
pub fn format_table(title: &str, rows: &[(&str, String)]) -> String {
    let mut t = Table::new(&["Metric", "Value"]);
    for (k, v) in rows {
        t.row(vec![k.to_string(), v.clone()]);
    }
    format!("== {title} ==\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(vec!["xxxxx".into(), "1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("long-header"));
        assert!(lines[2].starts_with("xxxxx"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        Table::new(&["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn csv_rendering() {
        let mut t = Table::new(&["x", "y"]);
        t.row(vec!["1".into(), "a,b".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,\"a,b\"\n");
    }

    #[test]
    fn series_format() {
        let s = format_row_series("fig", &[(1.0, 2.0), (3.0, 4.5)]);
        assert!(s.starts_with("# fig\n"));
        assert!(s.contains("3.0000\t4.5000"));
    }
}
