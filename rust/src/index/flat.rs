//! Exact top-k dot-product index over unit vectors.
//!
//! This is the retrieval stage of the paper (`i* = argmax_i <e_i, e_t>`),
//! as an explicit, removal-capable structure: entries carry a caller key
//! (the KV store id) so eviction keeps the two structures in sync. L1's
//! `sim_topk.py` is the TPU-shaped twin of the scoring loop.

/// Flat exact-search index. Keys are caller-owned u64s (KV store ids).
#[derive(Debug, Default)]
pub struct FlatIndex {
    dim: usize,
    keys: Vec<u64>,
    /// Row-major [n, dim] matrix.
    vectors: Vec<f32>,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        FlatIndex {
            dim,
            keys: Vec::new(),
            vectors: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Add a vector under a key. Panics on dimension mismatch (programmer
    /// error — embedder dim is fixed at construction).
    pub fn add(&mut self, key: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "index dim mismatch");
        self.keys.push(key);
        self.vectors.extend_from_slice(vector);
    }

    /// Remove a key (swap-remove; O(dim)). Returns whether it existed.
    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(i) = self.keys.iter().position(|&k| k == key) {
            let last = self.keys.len() - 1;
            self.keys.swap(i, last);
            self.keys.pop();
            if i != last {
                let (head, tail) = self.vectors.split_at_mut(last * self.dim);
                head[i * self.dim..(i + 1) * self.dim].copy_from_slice(tail);
            }
            self.vectors.truncate(last * self.dim);
            true
        } else {
            false
        }
    }

    /// Dot-product scores against all entries (the hot loop; L1 twin:
    /// kernels/sim_topk.py). Four independent accumulators break the
    /// serial FP dependency chain so the loop vectorizes/pipelines; the
    /// summation order is fixed (pairwise) and identical across calls.
    ///
    /// Degenerate inputs (a NaN/Inf component anywhere in the query or a
    /// stored row) clamp that pair's score to 0.0 — "no similarity" —
    /// instead of letting a NaN poison the `top_k` ordering and eject
    /// valid candidates. Zero-norm embeddings (an empty prompt through
    /// the ngram embedder) already score 0.0 against everything.
    pub fn scores(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let mut out = Vec::with_capacity(self.keys.len());
        for row in self.vectors.chunks_exact(self.dim.max(1)) {
            let mut acc = [0f32; 4];
            let mut r4 = row.chunks_exact(4);
            let mut q4 = query.chunks_exact(4);
            for (r, q) in (&mut r4).zip(&mut q4) {
                acc[0] += r[0] * q[0];
                acc[1] += r[1] * q[1];
                acc[2] += r[2] * q[2];
                acc[3] += r[3] * q[3];
            }
            let mut dot = (acc[0] + acc[2]) + (acc[1] + acc[3]);
            for (&a, &b) in r4.remainder().iter().zip(q4.remainder()) {
                dot += a * b;
            }
            out.push(if dot.is_finite() { dot } else { 0.0 });
        }
        out
    }

    /// Top-k (key, score) pairs, best first — higher score wins, ties
    /// break toward the lower key. k=1 is the paper's retrieval.
    ///
    /// Uses `select_nth_unstable_by` partial selection (O(n) expected)
    /// to isolate the k best before sorting only those k — the full
    /// O(n log n) sort of every entry is gone from the request path.
    pub fn top_k(&self, query: &[f32], k: usize) -> Vec<(u64, f32)> {
        if k == 0 || self.keys.is_empty() {
            return Vec::new();
        }
        let scores = self.scores(query);
        let mut pairs: Vec<(u64, f32)> = self.keys.iter().copied().zip(scores).collect();
        // total order: `scores` clamps non-finite dots, and `total_cmp`
        // keeps the selection well-defined even if a NaN ever slipped
        // through — ordering bugs here silently eject valid candidates
        let better = |a: &(u64, f32), b: &(u64, f32)| {
            b.1.total_cmp(&a.1).then(a.0.cmp(&b.0))
        };
        if k < pairs.len() {
            // partition: everything before index k "beats" everything after
            let _ = pairs.select_nth_unstable_by(k - 1, better);
            pairs.truncate(k);
        }
        pairs.sort_by(better);
        pairs
    }

    /// Best match, if any.
    pub fn nearest(&self, query: &[f32]) -> Option<(u64, f32)> {
        self.top_k(query, 1).into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(v: &[f32]) -> Vec<f32> {
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        v.iter().map(|x| x / n).collect()
    }

    #[test]
    fn nearest_finds_identical() {
        let mut ix = FlatIndex::new(3);
        ix.add(10, &unit(&[1.0, 0.0, 0.0]));
        ix.add(20, &unit(&[0.0, 1.0, 0.0]));
        ix.add(30, &unit(&[1.0, 1.0, 0.0]));
        let (k, s) = ix.nearest(&unit(&[0.0, 1.0, 0.0])).unwrap();
        assert_eq!(k, 20);
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn top_k_ordering_and_truncation() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        ix.add(2, &[0.9, 0.1]);
        ix.add(3, &[0.0, 1.0]);
        let top = ix.top_k(&[1.0, 0.0], 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        ix.add(2, &[0.0, 1.0]);
        ix.add(3, &[-1.0, 0.0]);
        assert!(ix.remove(1));
        assert!(!ix.remove(1));
        assert_eq!(ix.len(), 2);
        // 2 and 3 must still be retrievable with correct vectors
        assert_eq!(ix.nearest(&[0.0, 1.0]).unwrap().0, 2);
        assert_eq!(ix.nearest(&[-1.0, 0.0]).unwrap().0, 3);
    }

    #[test]
    fn remove_last_element() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        assert!(ix.remove(1));
        assert!(ix.is_empty());
        assert!(ix.nearest(&[1.0, 0.0]).is_none());
    }

    #[test]
    fn empty_index() {
        let ix = FlatIndex::new(4);
        assert!(ix.nearest(&[0.0; 4]).is_none());
        assert!(ix.top_k(&[0.0; 4], 3).is_empty());
    }

    #[test]
    fn ties_break_by_key() {
        let mut ix = FlatIndex::new(1);
        ix.add(7, &[1.0]);
        ix.add(3, &[1.0]);
        assert_eq!(ix.nearest(&[1.0]).unwrap().0, 3);
    }

    #[test]
    fn ties_break_by_key_across_the_selection_boundary() {
        // five entries with identical scores: the k cut must keep the
        // lowest keys, in key order — the partial selection cannot be
        // allowed to keep an arbitrary tied subset.
        let mut ix = FlatIndex::new(1);
        for key in [9u64, 2, 7, 4, 11] {
            ix.add(key, &[1.0]);
        }
        let top = ix.top_k(&[1.0], 3);
        assert_eq!(top.iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![2, 4, 7]);
    }

    #[test]
    fn top_k_matches_full_sort_reference() {
        // partial selection vs the old full-sort implementation, over a
        // deterministic spread of scores, every k
        let dim = 7; // odd dim exercises the unrolled-loop remainder
        let mut ix = FlatIndex::new(dim);
        let n = 23u64;
        let mut rows = Vec::new();
        for key in 0..n {
            let v: Vec<f32> = (0..dim)
                .map(|j| ((key as usize * 31 + j * 17) % 13) as f32 - 6.0)
                .collect();
            ix.add(key, &v);
            rows.push((key, v));
        }
        // dyadic-rational query over small-integer rows: every product and
        // partial sum is exact in f32, so the unrolled accumulation and the
        // reference's serial sum agree bit-for-bit (keys 0 and 13 share a
        // row, so exact ties exercise the key tie-break in both paths)
        let q: Vec<f32> = (0..dim).map(|j| (j as f32 - 3.0) * 0.5).collect();
        let mut reference: Vec<(u64, f32)> = rows
            .iter()
            .map(|(k, v)| (*k, v.iter().zip(&q).map(|(a, b)| a * b).sum::<f32>()))
            .collect();
        reference.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for k in [0usize, 1, 2, 5, 22, 23, 50] {
            let got = ix.top_k(&q, k);
            let want = &reference[..k.min(reference.len())];
            assert_eq!(got.len(), want.len(), "k={k}");
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.0, w.0, "k={k}");
                assert!((g.1 - w.1).abs() < 1e-4, "k={k}: {} vs {}", g.1, w.1);
            }
        }
    }

    #[test]
    fn nan_embedding_scores_zero_and_never_panics() {
        // a poisoned (NaN) row must not break the selection comparator or
        // outrank finite candidates
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[f32::NAN, 0.0]);
        ix.add(2, &[1.0, 0.0]);
        ix.add(3, &[0.5, 0.0]);
        let top = ix.top_k(&[1.0, 0.0], 3);
        assert_eq!(top[0].0, 2);
        assert_eq!(top[1].0, 3);
        // the NaN row clamps to 0.0 instead of ejecting valid candidates
        let nan_entry = top.iter().find(|(k, _)| *k == 1).unwrap();
        assert_eq!(nan_entry.1, 0.0);
    }

    #[test]
    fn nan_query_is_clean_zero_everywhere() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        ix.add(2, &[0.0, 1.0]);
        let s = ix.scores(&[f32::NAN, f32::NAN]);
        assert!(s.iter().all(|&x| x == 0.0), "NaN query must clamp: {s:?}");
        // nearest still returns a well-defined (tie-broken) answer
        assert_eq!(ix.nearest(&[f32::NAN, f32::NAN]).unwrap().0, 1);
    }

    #[test]
    fn zero_norm_query_scores_zero() {
        // the ngram embedder maps an empty prompt to the zero vector; it
        // must score 0.0 against every entry (a clean miss under any
        // positive similarity threshold), not NaN
        let mut ix = FlatIndex::new(3);
        ix.add(1, &unit(&[1.0, 2.0, 3.0]));
        let s = ix.scores(&[0.0; 3]);
        assert_eq!(s, vec![0.0]);
    }

    #[test]
    fn top_k_zero_and_oversized() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        assert!(ix.top_k(&[1.0, 0.0], 0).is_empty());
        assert_eq!(ix.top_k(&[1.0, 0.0], 10).len(), 1);
    }
}
