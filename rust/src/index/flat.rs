//! Exact top-k dot-product index over unit vectors.
//!
//! This is the retrieval stage of the paper (`i* = argmax_i <e_i, e_t>`),
//! as an explicit, removal-capable structure: entries carry a caller key
//! (the KV store id) so eviction keeps the two structures in sync. L1's
//! `sim_topk.py` is the TPU-shaped twin of the scoring loop.

/// Flat exact-search index. Keys are caller-owned u64s (KV store ids).
#[derive(Debug, Default)]
pub struct FlatIndex {
    dim: usize,
    keys: Vec<u64>,
    /// Row-major [n, dim] matrix.
    vectors: Vec<f32>,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        FlatIndex {
            dim,
            keys: Vec::new(),
            vectors: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Add a vector under a key. Panics on dimension mismatch (programmer
    /// error — embedder dim is fixed at construction).
    pub fn add(&mut self, key: u64, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "index dim mismatch");
        self.keys.push(key);
        self.vectors.extend_from_slice(vector);
    }

    /// Remove a key (swap-remove; O(dim)). Returns whether it existed.
    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(i) = self.keys.iter().position(|&k| k == key) {
            let last = self.keys.len() - 1;
            self.keys.swap(i, last);
            self.keys.pop();
            if i != last {
                let (head, tail) = self.vectors.split_at_mut(last * self.dim);
                head[i * self.dim..(i + 1) * self.dim].copy_from_slice(tail);
            }
            self.vectors.truncate(last * self.dim);
            true
        } else {
            false
        }
    }

    /// Dot-product scores against all entries (the hot loop; L1 twin:
    /// kernels/sim_topk.py).
    pub fn scores(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let mut out = Vec::with_capacity(self.keys.len());
        for row in self.vectors.chunks_exact(self.dim) {
            let mut dot = 0f32;
            for (&a, &b) in row.iter().zip(query) {
                dot += a * b;
            }
            out.push(dot);
        }
        out
    }

    /// Top-k (key, score) pairs, best first. k=1 is the paper's retrieval.
    pub fn top_k(&self, query: &[f32], k: usize) -> Vec<(u64, f32)> {
        let scores = self.scores(query);
        let mut pairs: Vec<(u64, f32)> = self.keys.iter().copied().zip(scores).collect();
        pairs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        pairs.truncate(k);
        pairs
    }

    /// Best match, if any.
    pub fn nearest(&self, query: &[f32]) -> Option<(u64, f32)> {
        self.top_k(query, 1).into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(v: &[f32]) -> Vec<f32> {
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        v.iter().map(|x| x / n).collect()
    }

    #[test]
    fn nearest_finds_identical() {
        let mut ix = FlatIndex::new(3);
        ix.add(10, &unit(&[1.0, 0.0, 0.0]));
        ix.add(20, &unit(&[0.0, 1.0, 0.0]));
        ix.add(30, &unit(&[1.0, 1.0, 0.0]));
        let (k, s) = ix.nearest(&unit(&[0.0, 1.0, 0.0])).unwrap();
        assert_eq!(k, 20);
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn top_k_ordering_and_truncation() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        ix.add(2, &[0.9, 0.1]);
        ix.add(3, &[0.0, 1.0]);
        let top = ix.top_k(&[1.0, 0.0], 2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 2);
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        ix.add(2, &[0.0, 1.0]);
        ix.add(3, &[-1.0, 0.0]);
        assert!(ix.remove(1));
        assert!(!ix.remove(1));
        assert_eq!(ix.len(), 2);
        // 2 and 3 must still be retrievable with correct vectors
        assert_eq!(ix.nearest(&[0.0, 1.0]).unwrap().0, 2);
        assert_eq!(ix.nearest(&[-1.0, 0.0]).unwrap().0, 3);
    }

    #[test]
    fn remove_last_element() {
        let mut ix = FlatIndex::new(2);
        ix.add(1, &[1.0, 0.0]);
        assert!(ix.remove(1));
        assert!(ix.is_empty());
        assert!(ix.nearest(&[1.0, 0.0]).is_none());
    }

    #[test]
    fn empty_index() {
        let ix = FlatIndex::new(4);
        assert!(ix.nearest(&[0.0; 4]).is_none());
        assert!(ix.top_k(&[0.0; 4], 3).is_empty());
    }

    #[test]
    fn ties_break_by_key() {
        let mut ix = FlatIndex::new(1);
        ix.add(7, &[1.0]);
        ix.add(3, &[1.0]);
        assert_eq!(ix.nearest(&[1.0]).unwrap().0, 3);
    }
}
