//! Hashed character-n-gram embedder.
//!
//! Substitution for sentence-transformers (DESIGN.md §2): each character
//! 3/4/5-gram hashes into one of `dim` buckets with a signed weight; the
//! bucket histogram is L2-normalized. Prompts sharing long literal spans —
//! exactly the paper's near-duplicate / extended-prefix workloads — land
//! close in cosine space, which is all the retrieval stage needs.

use super::Embedder;

/// FNV-1a 64-bit (no external hash crates needed, stable across runs).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hashed n-gram embedding with fixed output dimension.
#[derive(Debug, Clone)]
pub struct NgramEmbedder {
    dim: usize,
    ngram_sizes: Vec<usize>,
}

impl NgramEmbedder {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        NgramEmbedder {
            dim,
            ngram_sizes: vec![3, 4, 5],
        }
    }

    pub fn with_ngram_sizes(dim: usize, sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty());
        NgramEmbedder {
            dim,
            ngram_sizes: sizes,
        }
    }
}

impl Embedder for NgramEmbedder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn embed(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0f32; self.dim];
        let lower = text.to_lowercase();
        let bytes = lower.as_bytes();
        for &n in &self.ngram_sizes {
            if bytes.len() < n {
                continue;
            }
            for w in bytes.windows(n) {
                let h = fnv1a(w);
                let bucket = (h % self.dim as u64) as usize;
                let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
                v[bucket] += sign;
            }
        }
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in &mut v {
                *x /= norm;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::cosine;

    #[test]
    fn unit_norm() {
        let e = NgramEmbedder::new(64);
        let v = e.embed("What is the capital of France?");
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic() {
        let e = NgramEmbedder::new(64);
        assert_eq!(e.embed("hello"), e.embed("hello"));
    }

    #[test]
    fn near_duplicates_score_higher_than_unrelated() {
        let e = NgramEmbedder::new(128);
        let cache = e.embed("What is the capital of France?");
        let extended =
            e.embed("What is the capital of France? Also mention a nearby tourist destination.");
        let unrelated = e.embed("How do rockets launch?");
        assert!(
            cosine(&cache, &extended) > cosine(&cache, &unrelated) + 0.2,
            "ext={} unrel={}",
            cosine(&cache, &extended),
            cosine(&cache, &unrelated)
        );
    }

    #[test]
    fn case_insensitive() {
        let e = NgramEmbedder::new(64);
        assert_eq!(e.embed("Hello World"), e.embed("hello world"));
    }

    #[test]
    fn short_and_empty_inputs() {
        let e = NgramEmbedder::new(64);
        assert_eq!(e.embed("").iter().map(|x| x * x).sum::<f32>(), 0.0);
        let _ = e.embed("ab"); // shorter than every n-gram: zero vector, no panic
    }
}
