//! Embedding index — the faiss-cpu + sentence-transformers substitute.
//!
//! * [`ngram::NgramEmbedder`] — hashed character-n-gram embedding on the
//!   request path (deterministic, no model call).
//! * [`flat::FlatIndex`] — exact top-k dot-product search over normalized
//!   vectors (the same algorithm faiss's `IndexFlatIP` runs at this scale,
//!   and the paper's `argmax_i <e_i, e_t>` retrieval).
//!
//! Two recycler tiers run on these primitives, as two separate
//! `FlatIndex` instances inside `recycler`:
//!
//! * **whole-prompt index** — one vector per cached record; tier-1
//!   exact-prefix retrieval (`RecyclePolicy::Strict`).
//! * **segment index** — one vector per fixed-stride token span of each
//!   record; tier-2 segment lookup, where a semantic nearest-neighbour
//!   only *proposes* a span and exact token comparison decides whether
//!   it can be re-anchored.
//!
//! Degenerate inputs are clamped rather than propagated: [`cosine`]
//! defines the zero-vector cases below, and `FlatIndex` treats a
//! non-finite or zero-norm query/entry score as "no match" instead of
//! letting a NaN poison the comparator (see `flat::tests`).
//!
//! An alternative embedder backed by the AOT `embed.hlo.txt` artifact lives
//! in `engine::embedder` (it needs the PJRT runtime).

mod flat;
mod ngram;

pub use flat::FlatIndex;
pub use ngram::NgramEmbedder;

/// Anything that can embed text into a unit-norm vector.
///
/// Not `Send`/`Sync`-bounded: the HLO-backed embedder holds PJRT handles,
/// which live on a single thread (the coordinator worker).
pub trait Embedder {
    fn dim(&self) -> usize;
    fn embed(&self, text: &str) -> Vec<f32>;
}

/// Cosine similarity between two (not necessarily normalized) vectors.
/// Two zero vectors compare as 1.0 (identical inputs, e.g. two empty
/// texts); a zero vector against a non-zero one compares as 0.0.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f32, 0f32, 0f32);
    for (&x, &y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    match (na == 0.0, nb == 0.0) {
        (true, true) => 1.0,
        (true, false) | (false, true) => 0.0,
        _ => dot / (na.sqrt() * nb.sqrt()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
        assert_eq!(cosine(&[0.0, 0.0], &[0.0, 0.0]), 1.0);
    }
}
