//! Deterministic scheduler-trace harness.
//!
//! Drives the coordinator's [`Scheduler`] **tick-by-tick** — no worker
//! thread, no wall-clock coupling — with a scripted arrival schedule, and
//! records the full per-tick [`SchedEvent`] trace plus every request's
//! final output. Any interleaving of admissions, prefill chunks, decode
//! dispatches, deferrals, and completions is therefore replayable
//! bit-for-bit from its [`Script`] (and, inside a property test, from the
//! seed that generated the script — see [`crate::testutil::prop`]).
//!
//! Used by `rust/tests/properties.rs` to prove the chunked-prefill
//! scheduler token-identical to inline/sequential serving across random
//! schedules, and by the head-of-line regression tests to assert that
//! in-flight decode streams keep progressing while a long cache-cold
//! prompt prefills.
//!
//! On a failure, [`shrink_script`] greedily minimizes the reproducing
//! schedule: it drops arrivals one at a time and flattens arrival ticks
//! toward zero while the failure predicate still holds, so the panic
//! message carries the smallest script that still fails rather than the
//! random one that happened to be generated.

use std::sync::mpsc;
use std::time::Instant;

use crate::config::ServerConfig;
use crate::coordinator::{Request, Response, SchedEvent, Scheduler, StreamEvent};
use crate::metrics::SchedulerStats;
use crate::recycler::Recycler;
use crate::testutil::MockModel;

/// One scripted request: enters the scheduler's arrival set at `at_tick`.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at_tick: usize,
    pub prompt: String,
    pub max_new: usize,
    pub session: Option<String>,
}

/// A deterministic arrival schedule. Arrivals sharing a tick are delivered
/// in script order (script index == request id - 1).
#[derive(Debug, Clone, Default)]
pub struct Script {
    pub arrivals: Vec<Arrival>,
}

/// Everything one scripted run produced.
pub struct TraceRun {
    /// `(tick, event)` in execution order.
    pub events: Vec<(usize, SchedEvent)>,
    /// Per-arrival outcome (index == script index): generated token ids,
    /// or the error message the scheduler replied with.
    pub outputs: Vec<std::result::Result<Vec<u32>, String>>,
    /// Ticks the run took to drain.
    pub ticks: usize,
    /// Scheduler counters at the end of the run.
    pub stats: SchedulerStats,
    /// Per-arrival streamed events (index == script index): every request
    /// runs with a stream channel attached, so the streaming-identity
    /// property can compare tokens-as-emitted against the aggregate reply
    /// for ANY script, faulty or not.
    pub streams: Vec<Vec<StreamEvent>>,
}

impl TraceRun {
    /// All events of one tick (assertion convenience).
    pub fn tick_events(&self, tick: usize) -> Vec<&SchedEvent> {
        self.events
            .iter()
            .filter(|(t, _)| *t == tick)
            .map(|(_, e)| e)
            .collect()
    }

    /// The tick a given event first matches on, if any.
    pub fn first_tick_where(&self, mut pred: impl FnMut(&SchedEvent) -> bool) -> Option<usize> {
        self.events
            .iter()
            .find(|(_, e)| pred(e))
            .map(|(t, _)| *t)
    }
}

/// Run a script to completion: construct the scheduler from `mk_recycler`,
/// deliver each arrival at its tick, tick until every request has replied
/// and the scheduler is idle. Errors (with the full trace attached) if the
/// run does not converge within `max_ticks`.
pub fn run_script<F>(
    mk_recycler: F,
    cfg: ServerConfig,
    script: &Script,
    max_ticks: usize,
) -> std::result::Result<TraceRun, String>
where
    F: FnOnce() -> Recycler<MockModel>,
{
    let mut sched = Scheduler::new(mk_recycler(), cfg);
    let mut events: Vec<(usize, SchedEvent)> = Vec::new();
    let mut outputs: Vec<Option<std::result::Result<Vec<u32>, String>>> =
        vec![None; script.arrivals.len()];
    let mut pending_rx: Vec<(usize, mpsc::Receiver<Response>)> = Vec::new();
    let mut stream_rx: Vec<Option<mpsc::Receiver<StreamEvent>>> =
        (0..script.arrivals.len()).map(|_| None).collect();
    let last_arrival = script
        .arrivals
        .iter()
        .map(|a| a.at_tick)
        .max()
        .unwrap_or(0);
    let mut tick = 0usize;
    loop {
        let fresh: Vec<Request> = script
            .arrivals
            .iter()
            .enumerate()
            .filter(|(_, a)| a.at_tick == tick)
            .map(|(i, a)| {
                let (tx, rx) = mpsc::channel();
                pending_rx.push((i, rx));
                let (stx, srx) = mpsc::channel();
                stream_rx[i] = Some(srx);
                Request {
                    id: i as u64 + 1,
                    prompt: a.prompt.clone(),
                    max_new_tokens: a.max_new,
                    session: a.session.clone(),
                    reply: tx,
                    queued_at: Instant::now(),
                    tenant: None,
                    stream: Some(stx),
                }
            })
            .collect();
        let out = sched.tick(fresh);
        for (tx, resp) in out.replies {
            let _ = tx.send(resp);
        }
        for ev in out.events {
            events.push((tick, ev));
        }
        pending_rx.retain(|(i, rx)| match rx.try_recv() {
            Ok(Response::Ok(out)) => {
                outputs[*i] = Some(Ok(out.ids));
                false
            }
            Ok(Response::Err { msg, .. }) => {
                outputs[*i] = Some(Err(msg));
                false
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                outputs[*i] = Some(Err("request dropped without reply".into()));
                false
            }
            Err(mpsc::TryRecvError::Empty) => true,
        });
        if tick >= last_arrival && sched.is_idle() && pending_rx.is_empty() {
            break;
        }
        tick += 1;
        if tick > max_ticks {
            return Err(format!(
                "script did not converge within {max_ticks} ticks \
                 ({} of {} replies); trace:\n{events:#?}",
                outputs.iter().filter(|o| o.is_some()).count(),
                outputs.len(),
            ));
        }
    }
    let outputs = outputs
        .into_iter()
        .map(|o| o.unwrap_or_else(|| Err("request never completed".into())))
        .collect();
    // drain the streamed mirror of each request (senders are gone once the
    // scheduler is idle, so try_iter sees the complete event sequence)
    let streams = stream_rx
        .into_iter()
        .map(|rx| rx.map(|rx| rx.try_iter().collect()).unwrap_or_default())
        .collect();
    Ok(TraceRun {
        events,
        outputs,
        ticks: tick + 1,
        stats: sched.stats(),
        streams,
    })
}

/// Greedily minimize a failing script: while `fails` still holds, drop
/// arrivals one at a time, then flatten arrival ticks to 0 (the smallest
/// interleaving). Deterministic — same input, same minimal script. The
/// predicate must be pure (it is re-run on every candidate).
pub fn shrink_script<F>(script: &Script, mut fails: F) -> Script
where
    F: FnMut(&Script) -> bool,
{
    let mut cur = script.clone();
    loop {
        let mut shrunk = false;
        let mut i = 0;
        while cur.arrivals.len() > 1 && i < cur.arrivals.len() {
            let mut cand = cur.clone();
            cand.arrivals.remove(i);
            if fails(&cand) {
                cur = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }
        for i in 0..cur.arrivals.len() {
            if cur.arrivals[i].at_tick > 0 {
                let mut cand = cur.clone();
                cand.arrivals[i].at_tick = 0;
                if fails(&cand) {
                    cur = cand;
                    shrunk = true;
                }
            }
        }
        if !shrunk {
            break;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ServerConfig};
    use crate::engine::Engine;
    use crate::index::NgramEmbedder;
    use crate::recycler::RecyclePolicy;
    use crate::tokenizer::Tokenizer;
    use std::sync::Arc;

    fn mk_recycler() -> Recycler<MockModel> {
        Recycler::new(
            Engine::new(MockModel::new(ModelConfig::nano())),
            Arc::new(Tokenizer::new(vec![])),
            Box::new(NgramEmbedder::new(64)),
            Default::default(),
            RecyclePolicy::Strict,
        )
    }

    fn arrival(at_tick: usize, prompt: &str, max_new: usize) -> Arrival {
        Arrival {
            at_tick,
            prompt: prompt.into(),
            max_new,
            session: None,
        }
    }

    #[test]
    fn scripted_run_records_full_lifecycle() {
        let script = Script {
            arrivals: vec![
                arrival(0, "the first scripted prompt", 3),
                arrival(2, "the second one arrives later", 2),
            ],
        };
        let run = run_script(mk_recycler, ServerConfig::default(), &script, 1000).unwrap();
        assert_eq!(run.outputs.len(), 2);
        assert_eq!(run.outputs[0].as_ref().unwrap().len(), 3);
        assert_eq!(run.outputs[1].as_ref().unwrap().len(), 2);
        // the trace shows the full state machine for request 1
        let admitted = run
            .first_tick_where(|e| matches!(e, SchedEvent::Admitted { id: 1 }))
            .expect("request 1 admitted");
        assert_eq!(admitted, 0, "tick-0 arrival admits at tick 0");
        assert!(run
            .events
            .iter()
            .any(|(_, e)| matches!(e, SchedEvent::PrefillChunk { id: 1, .. })));
        assert!(run
            .events
            .iter()
            .any(|(_, e)| matches!(e, SchedEvent::DecodeStep { .. })));
        assert!(run
            .events
            .iter()
            .any(|(_, e)| matches!(e, SchedEvent::FirstToken { id: 1 })));
        assert!(run
            .events
            .iter()
            .any(|(_, e)| matches!(e, SchedEvent::Finished { id: 1, tokens: 3 })));
        // request 2 must not be admitted before its scripted tick
        let adm2 = run
            .first_tick_where(|e| matches!(e, SchedEvent::Admitted { id: 2 }))
            .expect("request 2 admitted");
        assert!(adm2 >= 2, "arrival at tick 2 admitted at {adm2}");
        // the streamed mirror: per-token events then exactly one End,
        // token-identical to the aggregate reply
        assert_eq!(run.streams.len(), 2);
        for (i, stream) in run.streams.iter().enumerate() {
            let ids: Vec<u32> = stream
                .iter()
                .filter_map(|e| match e {
                    StreamEvent::Token { id, .. } => Some(*id),
                    StreamEvent::End(_) => None,
                })
                .collect();
            assert_eq!(&ids, run.outputs[i].as_ref().unwrap(), "stream {i}");
            assert!(
                matches!(stream.last(), Some(StreamEvent::End(Response::Ok(_)))),
                "stream {i} must end with a successful End event"
            );
        }
    }

    #[test]
    fn same_tick_arrivals_deliver_in_script_order() {
        let script = Script {
            arrivals: vec![
                arrival(0, "aaaa bbbb cccc", 2),
                arrival(0, "dddd eeee ffff", 2),
            ],
        };
        // one prefill slot: the second arrival must defer behind the first
        let cfg = ServerConfig {
            max_prefilling_slots: 1,
            prefill_chunk_tokens: 8,
            ..Default::default()
        };
        let run = run_script(mk_recycler, cfg, &script, 1000).unwrap();
        let a1 = run
            .first_tick_where(|e| matches!(e, SchedEvent::Admitted { id: 1 }))
            .unwrap();
        let a2 = run
            .first_tick_where(|e| matches!(e, SchedEvent::Admitted { id: 2 }))
            .unwrap();
        assert!(a1 <= a2, "script order preserved under the slot gate");
        assert!(run.outputs.iter().all(|o| o.is_ok()));
    }

    #[test]
    fn shrink_drops_irrelevant_arrivals() {
        let script = Script {
            arrivals: vec![
                arrival(0, "innocent bystander", 1),
                arrival(3, "the culprit", 1),
                arrival(5, "another bystander", 1),
            ],
        };
        // predicate: fails whenever "culprit" is scheduled at all
        let minimal = shrink_script(&script, |s| {
            s.arrivals.iter().any(|a| a.prompt.contains("culprit"))
        });
        assert_eq!(minimal.arrivals.len(), 1);
        assert!(minimal.arrivals[0].prompt.contains("culprit"));
        assert_eq!(minimal.arrivals[0].at_tick, 0, "tick flattened to 0");
    }
}
