//! Deterministic fake model.
//!
//! The mock satisfies the ForwardModel contract *including the paper's
//! exactness property*: it stores a marker for each token into the paged KV
//! view (plane `[layer 0, K, head 0, pos, 0]`) and derives logits purely
//! from the markers of the visible prefix — so KV injection behaves exactly
//! like the real model (recycled == baseline), and corrupted/shifted KV
//! shows up as divergent outputs. Its reads and writes go through the
//! [`KvView`] row accessors, exercising the same COW/sharing machinery the
//! production gather/scatter path uses.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::ModelConfig;
use crate::engine::{BatchItem, ForwardModel};
use crate::error::{Error, Result};
use crate::faults::{FaultHandle, FaultSite};
use crate::kvcache::KvView;

pub struct MockModel {
    cfg: ModelConfig,
    /// Simulated per-token encode cost (for cost-model benches).
    pub delay_per_token: Duration,
    /// Live-tunable override of `delay_per_token` in nanoseconds, shared
    /// with whoever installed it: phase-structured benches reprice the
    /// cost model mid-run (e.g. a free cache-population warmup, then a
    /// priced measured window) without rebuilding the serving stack.
    shared_delay_ns: Option<Arc<AtomicU64>>,
    /// Fail the Nth forward call (failure injection).
    fail_on_call: Option<usize>,
    /// Plan-driven fault seam (inert unless a `FaultPlan` is installed).
    faults: FaultHandle,
    calls: AtomicUsize,
}

impl MockModel {
    pub fn new(cfg: ModelConfig) -> Self {
        MockModel {
            cfg,
            delay_per_token: Duration::ZERO,
            shared_delay_ns: None,
            fail_on_call: None,
            faults: FaultHandle::off(),
            calls: AtomicUsize::new(0),
        }
    }

    pub fn with_delay(cfg: ModelConfig, per_token: Duration) -> Self {
        MockModel {
            delay_per_token: per_token,
            ..Self::new(cfg)
        }
    }

    /// A mock whose per-token cost is re-read from `ns` (nanoseconds; 0 =
    /// free) at every forward call, so the owner of the atomic can retune
    /// the cost model while the model is serving.
    pub fn with_shared_delay(cfg: ModelConfig, ns: Arc<AtomicU64>) -> Self {
        MockModel {
            shared_delay_ns: Some(ns),
            ..Self::new(cfg)
        }
    }

    /// The effective per-token cost right now (shared knob wins).
    fn per_token_cost(&self) -> Duration {
        match &self.shared_delay_ns {
            Some(ns) => Duration::from_nanos(ns.load(Ordering::Relaxed)),
            None => self.delay_per_token,
        }
    }

    /// Make the `n`-th forward call (1-based) return an error.
    pub fn fail_on_call(mut self, n: usize) -> Self {
        self.fail_on_call = Some(n);
        self
    }

    /// Attach a fault plan (the `ForwardModel` failure-domain seam:
    /// `ModelTransient`, `ModelPermanent`, `ModelSlow` fire per forward
    /// call).
    pub fn with_faults(mut self, h: FaultHandle) -> Self {
        self.faults = h;
        self
    }

    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }

    /// The shared forward body; `with_delay` gates the simulated per-token
    /// cost so the batched entry point can model one device dispatch for
    /// the whole batch instead of a per-lane sum.
    fn forward_one(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
        with_delay: bool,
    ) -> Result<Vec<f32>> {
        let n = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        if self.fail_on_call == Some(n) {
            return Err(Error::Xla("injected failure".into()));
        }
        if self.faults.roll(FaultSite::ModelTransient) {
            return Err(Error::Xla("injected transient model fault".into()));
        }
        if self.faults.roll(FaultSite::ModelPermanent) {
            return Err(Error::ShapeMismatch("injected permanent model fault".into()));
        }
        if self.faults.roll(FaultSite::ModelSlow) {
            if let Some(d) = self.faults.slow_step() {
                std::thread::sleep(d);
            }
        }
        let c = tokens.len();
        let v = self.cfg.vocab_size;
        // A chunk must be a compiled bucket — except the engine's unpadded
        // final chunk near the context window (the shared ForwardModel
        // contract predicate; the PJRT executor runs that shape
        // token-by-token through its 1-bucket).
        let bucket_ok = self.cfg.chunk_sizes.contains(&c)
            || self.cfg.unpadded_chunk_legal(c, valid_len, cur_len);
        if !bucket_ok {
            return Err(Error::ShapeMismatch(format!("chunk {c} not a bucket")));
        }
        if !kv.geometry().matches(&self.cfg) {
            return Err(Error::ShapeMismatch("kv geometry".into()));
        }
        if cur_len + c > self.cfg.max_seq {
            return Err(Error::ContextExhausted(cur_len + c));
        }
        if valid_len == 0 || valid_len > c {
            return Err(Error::ShapeMismatch("valid_len".into()));
        }
        if cur_len > kv.len() {
            return Err(Error::ShapeMismatch("kv view shorter than cur_len".into()));
        }
        if with_delay {
            let d = self.per_token_cost();
            if !d.is_zero() {
                std::thread::sleep(d * valid_len as u32);
            }
        }
        // Write markers for the new valid tokens (COW-aware row writes).
        for (i, &t) in tokens[..valid_len].iter().enumerate() {
            kv.row_mut(0, 0, 0, cur_len + i)?[0] = (t + 1) as f32;
        }
        kv.commit(cur_len + valid_len);
        // Logits for every chunk row from the visible marker prefix.
        let mut logits = vec![0f32; c * v];
        for i in 0..valid_len {
            let pos = cur_len + i;
            let mut h: u64 = 0xcbf29ce484222325;
            for p in 0..=pos {
                let m = kv.row(0, 0, 0, p)[0] as u64;
                h = h.wrapping_mul(1000003).wrapping_add(m);
            }
            // Avoid the EOT id so greedy runs don't stop early; ids stay
            // in [1, v).
            let id = 1 + (h % (v as u64 - 1)) as usize;
            logits[i * v + id] = 1.0;
        }
        Ok(logits)
    }
}

impl ForwardModel for MockModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
    ) -> Result<Vec<f32>> {
        self.forward_one(tokens, valid_len, kv, cur_len, true)
    }

    /// Batched specialization: one simulated device dispatch for the whole
    /// batch — lanes run concurrently, so the modeled cost is the *slowest
    /// lane*, not the per-lane sum. This is what makes continuous batching
    /// show real throughput wins on the mock backend
    /// (`benches/ablation_batching.rs`); the token/KV semantics are
    /// identical to looping `forward_chunk`.
    fn forward_batch(&self, items: &mut [BatchItem<'_>]) -> Result<Vec<Vec<f32>>> {
        let d = self.per_token_cost();
        if !d.is_zero() {
            if let Some(mx) = items.iter().map(|it| it.valid_len).max() {
                std::thread::sleep(d * mx as u32);
            }
        }
        items
            .iter_mut()
            .map(|it| self.forward_one(it.tokens, it.valid_len, it.kv, it.cur_len, false))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvArena;

    fn arena(m: &MockModel) -> KvArena {
        KvArena::with_defaults(m.config())
    }

    #[test]
    fn chunk_split_invariance() {
        // one 32-chunk == two calls (8 then 1) for the logits at row 8
        let m = MockModel::new(ModelConfig::nano());
        let a = arena(&m);
        let ids: Vec<u32> = (10..19).collect();

        let mut kv1 = a.new_view();
        let mut padded = ids.clone();
        padded.resize(32, 0);
        let l1 = m.forward_chunk(&padded, 9, &mut kv1, 0).unwrap();
        let v = m.config().vocab_size;
        let row8: Vec<f32> = l1[8 * v..9 * v].to_vec();

        let mut kv2 = a.new_view();
        let l2a = m.forward_chunk(&ids[..8], 8, &mut kv2, 0).unwrap();
        let l2b = m.forward_chunk(&ids[8..9], 1, &mut kv2, 8).unwrap();
        assert_eq!(row8, l2b[..v].to_vec());
        drop(l2a);
        for p in 0..9 {
            assert_eq!(kv1.row(0, 0, 0, p), kv2.row(0, 0, 0, p), "pos {p}");
        }
    }

    #[test]
    fn shared_delay_is_retunable_mid_stream() {
        let knob = Arc::new(AtomicU64::new(Duration::from_millis(25).as_nanos() as u64));
        let m = MockModel::with_shared_delay(ModelConfig::nano(), knob.clone());
        let mut kv = arena(&m).new_view();
        let t0 = std::time::Instant::now();
        m.forward_chunk(&[1], 1, &mut kv, 0).unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "a priced forward must sleep the shared cost"
        );
        knob.store(0, Ordering::Relaxed);
        let t1 = std::time::Instant::now();
        m.forward_chunk(&[2], 1, &mut kv, 1).unwrap();
        assert!(
            t1.elapsed() < Duration::from_millis(20),
            "after repricing to 0 the old cost must not be slept"
        );
    }

    #[test]
    fn injected_failure_fires_once() {
        let m = MockModel::new(ModelConfig::nano()).fail_on_call(2);
        let mut kv = arena(&m).new_view();
        assert!(m.forward_chunk(&[1], 1, &mut kv, 0).is_ok());
        assert!(m.forward_chunk(&[2], 1, &mut kv, 1).is_err());
        assert!(m.forward_chunk(&[2], 1, &mut kv, 1).is_ok());
    }

    #[test]
    fn fault_plan_drives_forward_errors() {
        use crate::faults::{FaultPlan, FaultSite};
        // per-site op counters: call 2's transient fault short-circuits, so
        // the permanent site sees its 2nd op on forward call 3
        let h = FaultPlan::new(5)
            .script(FaultSite::ModelTransient, &[2])
            .script(FaultSite::ModelPermanent, &[2])
            .install();
        let m = MockModel::new(ModelConfig::nano()).with_faults(h.clone());
        let mut kv = arena(&m).new_view();
        assert!(m.forward_chunk(&[1], 1, &mut kv, 0).is_ok());
        match m.forward_chunk(&[2], 1, &mut kv, 1) {
            Err(e) => assert!(e.is_transient(), "ModelTransient must be retryable"),
            ok => panic!("expected transient fault, got {:?}", ok.map(|_| ())),
        }
        match m.forward_chunk(&[2], 1, &mut kv, 1) {
            Err(e) => assert!(!e.is_transient(), "ModelPermanent must be terminal"),
            ok => panic!("expected permanent fault, got {:?}", ok.map(|_| ())),
        }
        assert!(m.forward_chunk(&[2], 1, &mut kv, 1).is_ok());
        assert_eq!(h.total_injected(), 2);
    }

    #[test]
    fn unpadded_final_chunk_legal_only_near_window() {
        // buckets without 1: the engine's near-window fallback sends an
        // unpadded chunk when even the smallest bucket would spill.
        let mut cfg = ModelConfig::nano();
        cfg.chunk_sizes = vec![8, 32, 64];
        let m = MockModel::new(cfg.clone());
        let a = KvArena::with_defaults(m.config());

        // mid-window: 5 is not a bucket and padding to 8 fits -> rejected
        let mut kv = a.new_view();
        assert!(m.forward_chunk(&[1, 2, 3, 4, 5], 5, &mut kv, 0).is_err());

        // near the window (251 + 8 > 256): the unpadded 5-chunk is legal
        let mut kv = a.new_view();
        for pos in 0..251 {
            kv.row_mut(0, 0, 0, pos).unwrap()[0] = 1.0;
        }
        kv.commit(251);
        let logits = m.forward_chunk(&[1, 2, 3, 4, 5], 5, &mut kv, 251).unwrap();
        assert_eq!(logits.len(), 5 * cfg.vocab_size);
        assert_eq!(kv.len(), 256);
        // but a *padded* non-bucket chunk is still rejected there
        let mut kv2 = a.new_view();
        for pos in 0..251 {
            kv2.row_mut(0, 0, 0, pos).unwrap()[0] = 1.0;
        }
        kv2.commit(251);
        assert!(m.forward_chunk(&[1, 2, 3, 4, 0], 4, &mut kv2, 251).is_err());
    }

    #[test]
    fn forward_batch_matches_sequential_chunks() {
        let m = MockModel::new(ModelConfig::nano());
        let a = arena(&m);
        // two independent sequences, stepped one token each
        let mut kv_a = a.new_view();
        let mut kv_b = a.new_view();
        let la = m.forward_chunk(&[3], 1, &mut kv_a, 0).unwrap();
        let lb = m.forward_chunk(&[9], 1, &mut kv_b, 0).unwrap();
        // sequential reference for the second step
        let mut kv_a_ref = kv_a.clone();
        let mut kv_b_ref = kv_b.clone();
        let ra = m.forward_chunk(&[4], 1, &mut kv_a_ref, 1).unwrap();
        let rb = m.forward_chunk(&[10], 1, &mut kv_b_ref, 1).unwrap();
        drop((la, lb));
        // batched second step
        let (ta, tb) = ([4u32], [10u32]);
        let mut items = vec![
            crate::engine::BatchItem { tokens: &ta, valid_len: 1, kv: &mut kv_a, cur_len: 1 },
            crate::engine::BatchItem { tokens: &tb, valid_len: 1, kv: &mut kv_b, cur_len: 1 },
        ];
        let out = m.forward_batch(&mut items).unwrap();
        drop(items);
        assert_eq!(out[0], ra);
        assert_eq!(out[1], rb);
        assert_eq!(kv_a.to_contiguous(), kv_a_ref.to_contiguous());
        assert_eq!(kv_b.to_contiguous(), kv_b_ref.to_contiguous());
    }

    #[test]
    fn guards_fire() {
        let m = MockModel::new(ModelConfig::nano());
        let a = arena(&m);
        let mut kv = a.new_view();
        assert!(m.forward_chunk(&[1, 2], 2, &mut kv, 0).is_err()); // 2 not a bucket
        assert!(m.forward_chunk(&[1], 0, &mut kv, 0).is_err());
        // wrong arena geometry
        let mut other_cfg = ModelConfig::nano();
        other_cfg.n_layer = 2;
        let mut wrong = KvArena::new(&other_cfg, 16, 8).new_view();
        assert!(m.forward_chunk(&[1], 1, &mut wrong, 0).is_err());
        // context exhaustion
        assert!(m.forward_chunk(&[1], 1, &mut kv, 256).is_err());
        // cur_len beyond the view's valid prefix
        assert!(m.forward_chunk(&[1], 1, &mut kv, 5).is_err());
    }
}
