//! Test infrastructure: a mini property-testing harness (proptest is not in
//! the offline vendor set), a deterministic mock [`ForwardModel`] so the
//! coordinator/recycler stack can be tested without PJRT artifacts, and a
//! deterministic scheduler-trace harness ([`trace`]) that drives the
//! coordinator's tick loop with scripted arrivals and records the full
//! event trace for assertion, replay, and shrinking.
//!
//! [`ForwardModel`]: crate::engine::ForwardModel

mod mock;
pub mod prop;
pub mod trace;

pub use mock::MockModel;

/// RAII temporary directory for tests (the `tempfile` crate is not in the
/// offline vendor set): a fresh unique directory under the OS temp dir,
/// removed — files included — when the guard drops. Used as the spill
/// directory of tiered-store tests so CI leaves no stray spill files.
pub struct TempDir(std::path::PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "recycle_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).expect("create test tempdir");
        TempDir(dir)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.0
    }

    /// The path as an owned string (what `CacheConfig::spill_dir` takes).
    pub fn path_string(&self) -> String {
        self.0.to_string_lossy().into_owned()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
