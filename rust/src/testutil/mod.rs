//! Test infrastructure: a mini property-testing harness (proptest is not in
//! the offline vendor set), a deterministic mock [`ForwardModel`] so the
//! coordinator/recycler stack can be tested without PJRT artifacts, and a
//! deterministic scheduler-trace harness ([`trace`]) that drives the
//! coordinator's tick loop with scripted arrivals and records the full
//! event trace for assertion, replay, and shrinking.
//!
//! [`ForwardModel`]: crate::engine::ForwardModel

mod mock;
pub mod prop;
pub mod trace;

pub use mock::MockModel;
