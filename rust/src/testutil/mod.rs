//! Test infrastructure: a mini property-testing harness (proptest is not in
//! the offline vendor set) and a deterministic mock [`ForwardModel`] so the
//! coordinator/recycler stack can be tested without PJRT artifacts.

mod mock;
pub mod prop;

pub use mock::MockModel;
