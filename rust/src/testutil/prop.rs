//! Mini property-testing harness (proptest substitute).
//!
//! `check(name, iters, |rng| ...)` runs a property over seeded random
//! inputs. On failure it panics with the failing seed **and the exact
//! one-line command that replays it locally**:
//!
//! ```text
//! PALLAS_PROP_SEED=17 cargo test -q <test name>
//! ```
//!
//! Environment knobs (read once per `check` call):
//!
//! * `PALLAS_PROP_SEED=<n>` — run ONLY seed `n` of every property (the
//!   reproduction path: a CI property failure is one env var away from a
//!   local single-case rerun).
//! * `PALLAS_PROP_CASES=<k>` — multiply every property's iteration count
//!   by `k` (the CI slow lane runs the suite at 10×; the default `cargo
//!   test -q` stays fast at 1×).
//!
//! Input shrinking is the domain harness's job, not this one's — e.g. the
//! scheduler-trace harness ([`crate::testutil::trace::shrink_script`])
//! minimizes failing arrival schedules; here a printed seed already
//! re-runs the exact failing case.

use crate::util::rng::Rng;

/// Run `prop` for `iters` seeded iterations (times the
/// `PALLAS_PROP_CASES` multiplier, or only the `PALLAS_PROP_SEED` seed
/// when set); panic with the failing seed and its reproduction command.
pub fn check<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    iters: u64,
    prop: F,
) {
    let seed_override = std::env::var("PALLAS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let cases_mult = std::env::var("PALLAS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&m| m > 0)
        .unwrap_or(1);
    check_with(name, iters, seed_override, cases_mult, prop)
}

/// [`check`] with the environment knobs passed explicitly (unit-testable
/// without mutating process-global env vars).
pub fn check_with<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    iters: u64,
    seed_override: Option<u64>,
    cases_mult: u64,
    mut prop: F,
) {
    let seeds: Box<dyn Iterator<Item = u64>> = match seed_override {
        Some(s) => Box::new(std::iter::once(s)),
        None => Box::new(0..iters.saturating_mul(cases_mult.max(1))),
    };
    for seed in seeds {
        let mut rng = Rng::new(0x5EED_0000 + seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at seed {seed}: {msg}\n\
                 reproduce with: PALLAS_PROP_SEED={seed} cargo test -q"
            );
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Random token sequence of length in [lo, hi) with ids in [1, vocab).
pub fn tokens(rng: &mut Rng, lo: usize, hi: usize, vocab: u32) -> Vec<u32> {
    let n = rng.range(lo, hi);
    (0..n).map(|_| 1 + (rng.next_u64() % (vocab as u64 - 1)) as u32).collect()
}

/// Random printable ASCII-ish text (letters, digits, spaces, newlines).
pub fn text(rng: &mut Rng, max_len: usize) -> String {
    const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz ABCDEFGHIJ0123456789 \n.,?!";
    let n = rng.below(max_len + 1);
    (0..n)
        .map(|_| ALPHABET[rng.below(ALPHABET.len())] as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 10, "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn check_reports_failure() {
        check("failing", 10, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn seed_override_runs_exactly_that_seed() {
        // seed 3 under the base offset must be the ONLY case executed
        let mut seen = Vec::new();
        check_with("override", 1000, Some(3), 1, |rng| {
            // regenerate deterministically to identify the seed
            let fingerprint = rng.next_u64();
            seen.push(fingerprint);
            Ok(())
        });
        assert_eq!(seen.len(), 1, "override runs a single case");
        assert_eq!(seen[0], Rng::new(0x5EED_0000 + 3).next_u64());
    }

    #[test]
    fn cases_multiplier_scales_iterations() {
        let mut n = 0u64;
        check_with("mult", 7, None, 3, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 21);
        // zero multiplier is clamped to 1, never silently skipping the suite
        let mut m = 0u64;
        check_with("mult0", 5, None, 0, |_| {
            m += 1;
            Ok(())
        });
        assert_eq!(m, 5);
    }

    #[test]
    #[should_panic(expected = "PALLAS_PROP_SEED=4")]
    fn failure_message_names_the_reproduction_command() {
        check_with("repro", 10, None, 1, |rng| {
            let x = rng.next_u64();
            // fail deterministically at seed 4
            if x == Rng::new(0x5EED_0000 + 4).next_u64() {
                return Err("boom".into());
            }
            Ok(())
        });
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let t = tokens(&mut rng, 1, 20, 512);
            assert!(!t.is_empty() && t.len() < 20);
            assert!(t.iter().all(|&x| (1..512).contains(&x)));
            let s = text(&mut rng, 40);
            assert!(s.len() <= 40);
        }
    }
}
