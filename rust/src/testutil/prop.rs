//! Mini property-testing harness (proptest substitute).
//!
//! `check(name, iters, |rng| ...)` runs a property over seeded random
//! inputs; on failure it retries with the same seed to report the minimal
//! reproduction seed. No shrinking — seeds are printed so a failing case is
//! directly re-runnable, which is what debugging actually needs here.

use crate::util::rng::Rng;

/// Run `prop` for `iters` seeded iterations; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    iters: u64,
    mut prop: F,
) {
    for seed in 0..iters {
        let mut rng = Rng::new(0x5EED_0000 + seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Random token sequence of length in [lo, hi) with ids in [1, vocab).
pub fn tokens(rng: &mut Rng, lo: usize, hi: usize, vocab: u32) -> Vec<u32> {
    let n = rng.range(lo, hi);
    (0..n).map(|_| 1 + (rng.next_u64() % (vocab as u64 - 1)) as u32).collect()
}

/// Random printable ASCII-ish text (letters, digits, spaces, newlines).
pub fn text(rng: &mut Rng, max_len: usize) -> String {
    const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz ABCDEFGHIJ0123456789 \n.,?!";
    let n = rng.below(max_len + 1);
    (0..n)
        .map(|_| ALPHABET[rng.below(ALPHABET.len())] as char)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 10, "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn check_reports_failure() {
        check("failing", 10, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let t = tokens(&mut rng, 1, 20, 512);
            assert!(!t.is_empty() && t.len() < 20);
            assert!(t.iter().all(|&x| (1..512).contains(&x)));
            let s = text(&mut rng, 40);
            assert!(s.len() <= 40);
        }
    }
}
