//! PJRT runtime: loads the AOT artifacts (`artifacts/manifest.json` et al.)
//! and executes the per-bucket forward HLO plus the embedding HLO on the
//! PJRT CPU client. Python is never involved — this module is the whole
//! model-side request path.
//!
//! Contract with `python/compile/aot.py` (per bucket C):
//!
//! ```text
//! inputs : params…, tokens i32[C], valid_len i32[], kv f32[L,2,H,S,D], cur_len i32[]
//! outputs: (logits f32[C,V], new_kv_rows f32[L,2,H,C,D])
//! ```
//!
//! The engine owns the authoritative *host* KV as a paged
//! [`KvView`](crate::kvcache::KvView); the runtime gathers the live prefix
//! into a seq-bucketed dense scratch per call and scatters the returned
//! rows back into the view — returning only the chunk's rows (not the
//! whole buffer) halves device<->host traffic, and the gather uploads only
//! the smallest exported KV capacity covering the live span.
//!
//! # Feature gating
//!
//! The PJRT backend needs the `xla` crate plus the native xla_extension
//! library, neither of which is in the offline vendor set. The code sits
//! behind the off-by-default `pjrt` cargo feature and the `xla` dependency
//! is deliberately undeclared so default builds resolve offline — enabling
//! the feature requires also adding an `xla` line to `[dependencies]` in
//! Cargo.toml. Without it this module compiles an API-identical stub whose
//! [`Runtime::load`] reports the missing backend, so every caller (CLI,
//! examples, benches, integration tests) builds and degrades gracefully to
//! the mock-model path.

mod artifacts;
#[cfg(feature = "pjrt")]
mod client;
#[cfg(feature = "pjrt")]
mod executor;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use artifacts::{Manifest, TensorMeta};
#[cfg(feature = "pjrt")]
pub use client::Client;
#[cfg(feature = "pjrt")]
pub use executor::{EmbedExec, ForwardExec, HloEmbedder};
#[cfg(not(feature = "pjrt"))]
pub use stub::{EmbedExec, ForwardExec, HloEmbedder};

use std::path::Path;

use crate::config::ModelConfig;
use crate::engine::ForwardModel;
use crate::error::Result;
use crate::kvcache::KvView;
use crate::tokenizer::Tokenizer;

/// The fully-loaded serving runtime: tokenizer + forward executables +
/// embedding executable, with weights resident on device.
pub struct Runtime {
    manifest: Manifest,
    tokenizer: std::sync::Arc<Tokenizer>,
    forward: ForwardExec,
    embed: EmbedExec,
}

impl Runtime {
    /// Load everything from an artifact directory (built by `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = Client::new()?;
        let tokenizer =
            std::sync::Arc::new(Tokenizer::from_file(&dir.join(&manifest.tokenizer_file))?);
        let forward = ForwardExec::load(&client, dir, &manifest)?;
        let embed = EmbedExec::load(&client, dir, &manifest)?;
        Ok(Runtime {
            manifest,
            tokenizer,
            forward,
            embed,
        })
    }

    /// Built without the `pjrt` feature: still validates the artifact
    /// directory (so "artifacts missing" stays the clearest error), then
    /// reports the absent backend.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = Manifest::load(dir.as_ref())?;
        Err(crate::error::Error::Xla(
            "recycle-serve was built without the `pjrt` feature; add the `xla` \
             dependency to Cargo.toml and rebuild with --features pjrt \
             (requires the native xla_extension library)"
                .into(),
        ))
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.model
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn tokenizer(&self) -> std::sync::Arc<Tokenizer> {
        std::sync::Arc::clone(&self.tokenizer)
    }

    pub fn embedder(&self) -> &EmbedExec {
        &self.embed
    }

    pub fn forward_exec(&self) -> &ForwardExec {
        &self.forward
    }
}

impl ForwardModel for Runtime {
    fn config(&self) -> &ModelConfig {
        self.manifest().model_config()
    }

    fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
    ) -> Result<Vec<f32>> {
        self.forward.forward_chunk(tokens, valid_len, kv, cur_len)
    }
}
