//! PJRT runtime: loads the AOT artifacts (`artifacts/manifest.json` et al.)
//! and executes the per-bucket forward HLO plus the embedding HLO on the
//! PJRT CPU client. Python is never involved — this module is the whole
//! model-side request path.
//!
//! Contract with `python/compile/aot.py` (per bucket C):
//!
//! ```text
//! inputs : params…, tokens i32[C], valid_len i32[], kv f32[L,2,H,S,D], cur_len i32[]
//! outputs: (logits f32[C,V], new_kv_rows f32[L,2,H,C,D])
//! ```
//!
//! The engine owns the authoritative *host* KV buffer; the runtime uploads
//! it per call and splices the returned rows back in — returning only the
//! chunk's rows (not the whole buffer) halves device<->host traffic.

mod artifacts;
mod client;
mod executor;

pub use artifacts::{Manifest, TensorMeta};
pub use client::Client;
pub use executor::{EmbedExec, ForwardExec, HloEmbedder};

use std::path::Path;

use crate::config::ModelConfig;
use crate::engine::ForwardModel;
use crate::error::Result;
use crate::tokenizer::Tokenizer;

/// The fully-loaded serving runtime: tokenizer + forward executables +
/// embedding executable, with weights resident on device.
pub struct Runtime {
    manifest: Manifest,
    tokenizer: std::sync::Arc<Tokenizer>,
    forward: ForwardExec,
    embed: EmbedExec,
}

impl Runtime {
    /// Load everything from an artifact directory (built by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = Client::new()?;
        let tokenizer =
            std::sync::Arc::new(Tokenizer::from_file(&dir.join(&manifest.tokenizer_file))?);
        let forward = ForwardExec::load(&client, dir, &manifest)?;
        let embed = EmbedExec::load(&client, dir, &manifest)?;
        Ok(Runtime {
            manifest,
            tokenizer,
            forward,
            embed,
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.manifest.model
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn tokenizer(&self) -> std::sync::Arc<Tokenizer> {
        std::sync::Arc::clone(&self.tokenizer)
    }

    pub fn embedder(&self) -> &EmbedExec {
        &self.embed
    }

    pub fn forward_exec(&self) -> &ForwardExec {
        &self.forward
    }
}

impl ForwardModel for Runtime {
    fn config(&self) -> &ModelConfig {
        self.manifest().model_config()
    }

    fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut [f32],
        cur_len: usize,
    ) -> Result<Vec<f32>> {
        self.forward.forward_chunk(tokens, valid_len, kv, cur_len)
    }
}
