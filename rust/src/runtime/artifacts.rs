//! Artifact manifest + weight file loading.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// One tensor's slot in `weights.bin` (little-endian f32, contiguous).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

fn parse_tensor_table(arr: &[Value]) -> Result<Vec<TensorMeta>> {
    let mut table = Vec::with_capacity(arr.len());
    let mut expect_offset = 0usize;
    for t in arr {
        let shape = t
            .req_arr("shape")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| Error::ManifestInvalid("bad tensor dim".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let meta = TensorMeta {
            name: t.req_str("name")?.to_string(),
            shape,
            offset: t.req_usize("offset")?,
            bytes: t.req_usize("bytes")?,
        };
        if meta.bytes != 4 * meta.elems() {
            return Err(Error::ManifestInvalid(format!(
                "tensor {}: bytes {} != 4 * elems {}",
                meta.name,
                meta.bytes,
                meta.elems()
            )));
        }
        if meta.offset != expect_offset {
            return Err(Error::ManifestInvalid(format!(
                "tensor {}: offset {} not contiguous (expected {})",
                meta.name, meta.offset, expect_offset
            )));
        }
        expect_offset += meta.bytes;
        table.push(meta);
    }
    Ok(table)
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub tensors: Vec<TensorMeta>,
    pub embed_tensors: Vec<TensorMeta>,
    /// Logical artifact name ("forward_c8", "embed") -> file name.
    pub artifacts: HashMap<String, String>,
    pub weights_file: String,
    pub embed_weights_file: String,
    pub tokenizer_file: String,
    pub fixtures_file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::ArtifactMissing(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let version = v.req_usize("version")?;
        if version != 1 {
            return Err(Error::ManifestInvalid(format!("unknown version {version}")));
        }
        let model = ModelConfig::from_json(v.req("model")?)?;
        let tensors = parse_tensor_table(v.req_arr("tensors")?)?;
        let embed_tensors = parse_tensor_table(v.req_arr("embed_tensors")?)?;
        let mut artifacts = HashMap::new();
        if let Value::Obj(kvs) = v.req("artifacts")? {
            for (k, file) in kvs {
                artifacts.insert(
                    k.clone(),
                    file.as_str()
                        .ok_or_else(|| Error::ManifestInvalid("artifact not a string".into()))?
                        .to_string(),
                );
            }
        } else {
            return Err(Error::ManifestInvalid("artifacts must be an object".into()));
        }
        // Every (chunk, seq) bucket pair must have its artifact.
        for c in &model.chunk_sizes {
            for sq in &model.seq_buckets {
                if c > sq {
                    continue;
                }
                let key = format!("forward_c{c}_s{sq}");
                if !artifacts.contains_key(&key) {
                    return Err(Error::ManifestInvalid(format!("missing artifact {key}")));
                }
            }
        }
        Ok(Manifest {
            model,
            tensors,
            embed_tensors,
            artifacts,
            weights_file: v.req_str("weights")?.to_string(),
            embed_weights_file: v.req_str("embed_weights")?.to_string(),
            tokenizer_file: v.req_str("tokenizer")?.to_string(),
            fixtures_file: v.req_str("fixtures")?.to_string(),
        })
    }

    pub fn model_config(&self) -> &ModelConfig {
        &self.model
    }

    pub fn artifact_path(&self, dir: &Path, key: &str) -> Result<PathBuf> {
        self.artifacts
            .get(key)
            .map(|f| dir.join(f))
            .ok_or_else(|| Error::ArtifactMissing(key.to_string()))
    }

    /// Total bytes the tensor table declares.
    pub fn weights_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.bytes).sum()
    }
}

/// Load a weights file and split it into per-tensor f32 vectors (ordered as
/// the table — which is the calling convention of the forward HLO).
pub fn load_weights(path: &Path, table: &[TensorMeta]) -> Result<Vec<Vec<f32>>> {
    let raw = std::fs::read(path)
        .map_err(|e| Error::ArtifactMissing(format!("{}: {e}", path.display())))?;
    let want: usize = table.iter().map(|t| t.bytes).sum();
    if raw.len() != want {
        return Err(Error::ManifestInvalid(format!(
            "{}: {} bytes on disk, manifest declares {}",
            path.display(),
            raw.len(),
            want
        )));
    }
    let mut out = Vec::with_capacity(table.len());
    for t in table {
        let bytes = &raw[t.offset..t.offset + t.bytes];
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(vals);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_manifest() -> String {
        r#"{
          "version": 1,
          "model": {"name":"nano","n_layer":4,"n_head":4,"d_model":128,
                    "vocab_size":512,"max_seq":256,"d_ff":512,"head_dim":32,
                    "embed_dim":64,"embed_seq":64,"chunk_sizes":[1,8],
                    "seq_buckets":[256],"eot_id":0},
          "tensors": [
            {"name":"a","shape":[2,3],"offset":0,"bytes":24},
            {"name":"b","shape":[4],"offset":24,"bytes":16}
          ],
          "embed_tensors": [],
          "artifacts": {"forward_c1_s256":"f1.hlo.txt",
                        "forward_c8_s256":"f8.hlo.txt",
                        "embed":"e.hlo.txt"},
          "weights":"weights.bin",
          "embed_weights":"embed_weights.bin",
          "tokenizer":"tokenizer.json",
          "fixtures":"fixtures.json"
        }"#
        .to_string()
    }

    #[test]
    fn parse_ok() {
        let m = Manifest::parse(&minimal_manifest()).unwrap();
        assert_eq!(m.model.name, "nano");
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.weights_bytes(), 40);
        assert_eq!(m.artifacts["embed"], "e.hlo.txt");
    }

    #[test]
    fn rejects_gap_in_offsets() {
        let bad = minimal_manifest().replace("\"offset\":24", "\"offset\":28");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_wrong_byte_count() {
        let bad = minimal_manifest().replace("\"bytes\":16", "\"bytes\":12");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_bucket_artifact() {
        let bad = minimal_manifest()
            .replace("\"forward_c8_s256\":\"f8.hlo.txt\",", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_version() {
        let bad = minimal_manifest().replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn load_weights_roundtrip() {
        let dir = std::env::temp_dir().join("recycle_serve_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&path, &bytes).unwrap();
        let table = vec![
            TensorMeta { name: "a".into(), shape: vec![2, 3], offset: 0, bytes: 24 },
            TensorMeta { name: "b".into(), shape: vec![4], offset: 24, bytes: 16 },
        ];
        let w = load_weights(&path, &table).unwrap();
        assert_eq!(w[0], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w[1], vec![6.0, 7.0, 8.0, 9.0]);
        // size mismatch detected
        let short = vec![TensorMeta { name: "a".into(), shape: vec![2], offset: 0, bytes: 8 }];
        assert!(load_weights(&path, &short).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
