//! API-identical stand-ins for the PJRT executors, compiled when the
//! `pjrt` feature is off (the `xla` crate and its native xla_extension are
//! not in the offline vendor set). Nothing here is constructible through
//! public paths — [`super::Runtime::load`] refuses first — but the types
//! keep every downstream caller (CLI, examples, benches, integration
//! tests) compiling unchanged, per the "stub or gate missing deps" rule.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::index::Embedder;
use crate::kvcache::KvView;

fn disabled() -> Error {
    Error::Xla("PJRT backend disabled (built without the `pjrt` feature)".into())
}

/// Stub of the per-bucket forward executor.
pub struct ForwardExec {
    cfg: ModelConfig,
}

impl ForwardExec {
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Available chunk bucket sizes (ascending, deduped).
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.cfg.chunk_sizes.clone()
    }

    pub fn forward_chunk(
        &self,
        _tokens: &[u32],
        _valid_len: usize,
        _kv: &mut KvView,
        _cur_len: usize,
    ) -> Result<Vec<f32>> {
        Err(disabled())
    }
}

/// Stub of the sentence-embedding executable.
pub struct EmbedExec {
    cfg: ModelConfig,
}

impl EmbedExec {
    pub fn embed_tokens(&self, _tokens: &[u32]) -> Result<Vec<f32>> {
        Err(disabled())
    }
}

/// Stub of the HLO-backed embedder.
pub struct HloEmbedder {
    dim: usize,
}

impl HloEmbedder {
    pub fn new(
        exec: std::sync::Arc<EmbedExec>,
        _tokenizer: std::sync::Arc<crate::tokenizer::Tokenizer>,
    ) -> Self {
        HloEmbedder {
            dim: exec.cfg.embed_dim,
        }
    }
}

impl Embedder for HloEmbedder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn embed(&self, _text: &str) -> Vec<f32> {
        vec![0.0; self.dim]
    }
}
