//! Thin PJRT client wrapper: compile HLO text files, create device buffers.

use std::path::Path;

use crate::error::{Error, Result};

/// PJRT CPU client handle (cheaply cloneable; the underlying client is
/// reference-counted by the xla crate).
#[derive(Clone)]
pub struct Client {
    inner: xla::PjRtClient,
}

impl Client {
    /// Create the CPU client (the only backend in this environment; real
    /// TPU deployment would switch on platform here).
    pub fn new() -> Result<Self> {
        Ok(Client {
            inner: xla::PjRtClient::cpu()?,
        })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Load HLO *text* (the AOT interchange format — serialized protos from
    /// jax >= 0.5 are rejected by xla_extension 0.5.1, see DESIGN.md) and
    /// compile it for this client.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            return Err(Error::ArtifactMissing(path.display().to_string()));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.inner.compile(&comp)?)
    }

    /// Upload an f32 tensor.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.inner.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.inner.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 scalar (rank-0).
    pub fn upload_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.upload_i32(&[v], &[])
    }
}
