//! Bucketed forward executor + embedding executor.
//!
//! Model weights are uploaded to device ONCE at load and passed to every
//! `execute_b` call as resident `PjRtBuffer`s — the request path never
//! re-uploads parameters, only the (small) tokens/scalars and the KV
//! buffer.

use std::path::Path;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::index::Embedder;
use crate::kvcache::KvView;

use super::artifacts::{load_weights, Manifest};
use super::client::Client;

/// One compiled forward bucket: (chunk size, KV sequence capacity).
struct Bucket {
    chunk: usize,
    seq: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The per-bucket forward executables with device-resident weights.
pub struct ForwardExec {
    client: Client,
    cfg: ModelConfig,
    params: Vec<xla::PjRtBuffer>,
    buckets: Vec<Bucket>,
    /// Scratch for seq-bucketed KV uploads (avoids an alloc per call).
    scratch: std::cell::RefCell<Vec<f32>>,
}

impl ForwardExec {
    pub fn load(client: &Client, dir: &Path, manifest: &Manifest) -> Result<Self> {
        let cfg = manifest.model.clone();
        // Upload weights once.
        let host = load_weights(&dir.join(&manifest.weights_file), &manifest.tensors)?;
        let mut params = Vec::with_capacity(host.len());
        for (vals, meta) in host.iter().zip(&manifest.tensors) {
            params.push(client.upload_f32(vals, &meta.shape)?);
        }
        // Compile one executable per (chunk, seq) bucket pair.
        let mut buckets = Vec::new();
        for &c in &cfg.chunk_sizes {
            for &sq in &cfg.seq_buckets {
                if c > sq {
                    continue;
                }
                let path = manifest.artifact_path(dir, &format!("forward_c{c}_s{sq}"))?;
                let exe = client.compile_hlo_file(&path)?;
                buckets.push(Bucket { chunk: c, seq: sq, exe });
            }
        }
        Ok(ForwardExec {
            client: client.clone(),
            cfg,
            params,
            buckets,
            scratch: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Available chunk bucket sizes (ascending, deduped).
    pub fn bucket_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.buckets.iter().map(|b| b.chunk).collect();
        v.sort();
        v.dedup();
        v
    }

    fn bucket(&self, chunk: usize, seq: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .find(|b| b.chunk == chunk && b.seq == seq)
            .ok_or_else(|| {
                Error::ShapeMismatch(format!("no bucket for chunk {chunk} seq {seq}"))
            })
    }

    /// Run one forward chunk.
    ///
    /// `tokens.len()` must equal a bucket size (right-pad before calling);
    /// `valid_len` of them are real. `kv` is the paged host KV view; the
    /// gather/scatter shim at this boundary keeps backend semantics
    /// identical to the old dense buffer: the live prefix is gathered into
    /// a seq-bucketed dense scratch (zero-padded past `cur_len`), and the
    /// returned rows are scattered back into the view at `cur_len`.
    /// Returns the logits `[C, V]` (flat, row-major).
    pub fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
    ) -> Result<Vec<f32>> {
        let c = tokens.len();
        let [l, two, h, s, d] = self.cfg.kv_shape();
        if !kv.geometry().matches(&self.cfg) {
            return Err(Error::ShapeMismatch(
                "kv view geometry does not match the model".into(),
            ));
        }
        if valid_len == 0 || valid_len > c {
            return Err(Error::ShapeMismatch(format!(
                "valid_len {valid_len} out of range for chunk {c}"
            )));
        }
        if cur_len + c > s {
            // dynamic_update_slice would clamp and silently corrupt: refuse.
            return Err(Error::ContextExhausted(cur_len + c));
        }
        if cur_len > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "kv view valid for {} positions, cur_len {cur_len}",
                kv.len()
            )));
        }
        // Near-window fallback: the engine sends an *unpadded* final chunk
        // when even the smallest compiled bucket would spill past the
        // context window (see Engine::prefill). No executable matches that
        // ad-hoc size, so execute it token-by-token through the 1-bucket —
        // exact by the chunk-split-invariance contract. Exported manifests
        // always include bucket 1 (the decode bucket); if one ever does
        // not, `bucket()` below still yields the clear missing-bucket
        // error instead of silently corrupting.
        // The legality predicate is shared with MockModel
        // (ModelConfig::unpadded_chunk_legal), so a mid-window non-bucket
        // chunk is a loud error on both backends instead of a silent slow
        // path here.
        if self.cfg.unpadded_chunk_legal(c, valid_len, cur_len)
            && c > 1
            && self.cfg.chunk_sizes.contains(&1)
        {
            let v = self.cfg.vocab_size;
            let mut logits = vec![0f32; c * v];
            for (i, &t) in tokens.iter().enumerate() {
                let row = self.forward_chunk(&[t], 1, kv, cur_len + i)?;
                logits[i * v..(i + 1) * v].copy_from_slice(&row);
            }
            return Ok(logits);
        }
        // Seq-bucket selection: the smallest exported KV capacity covering
        // the live span. Short contexts upload (and the attention kernel
        // scans) a fraction of the full window — the §Perf optimization.
        let sq = self.cfg.seq_bucket_for(cur_len + c);
        let bucket = self.bucket(c, sq)?;

        let tokens_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.client.upload_i32(&tokens_i32, &[c])?;
        let valid_buf = self.client.upload_i32_scalar(valid_len as i32)?;
        let kv_buf = {
            // Gather the live prefix from the paged view into the reusable
            // dense scratch (rows past cur_len stay zero — the attention
            // mask never reads them as real context).
            let mut scratch = self.scratch.borrow_mut();
            scratch.clear();
            scratch.resize(l * two * h * sq * d, 0.0);
            kv.gather_into(&mut scratch[..], sq, cur_len);
            self.client.upload_f32(&scratch, &[l, two, h, sq, d])?
        };
        let cur_buf = self.client.upload_i32_scalar(cur_len as i32)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&valid_buf);
        args.push(&kv_buf);
        args.push(&cur_buf);

        let result = bucket.exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 2 {
            return Err(Error::ShapeMismatch(format!(
                "forward returned {}-tuple, expected 2",
                parts.len()
            )));
        }
        let logits = parts[0].to_vec::<f32>()?;
        let rows = parts[1].to_vec::<f32>()?;
        if logits.len() != c * self.cfg.vocab_size {
            return Err(Error::ShapeMismatch("bad logits size".into()));
        }
        if rows.len() != l * two * h * c * d {
            return Err(Error::ShapeMismatch("bad kv rows size".into()));
        }
        // Scatter rows [L,2,H,C,D] into the paged view at cur_len. Only the
        // valid_len real rows are written (the padded tail is garbage by
        // contract); shared boundary blocks COW inside the view.
        kv.scatter_chunk(&rows, c, valid_len, cur_len)?;
        Ok(logits)
    }
}

/// The sentence-embedding executable (`embed.hlo.txt`).
pub struct EmbedExec {
    client: Client,
    cfg: ModelConfig,
    params: Vec<xla::PjRtBuffer>,
    exe: xla::PjRtLoadedExecutable,
}

impl EmbedExec {
    pub fn load(client: &Client, dir: &Path, manifest: &Manifest) -> Result<Self> {
        let host = load_weights(
            &dir.join(&manifest.embed_weights_file),
            &manifest.embed_tensors,
        )?;
        let mut params = Vec::with_capacity(host.len());
        for (vals, meta) in host.iter().zip(&manifest.embed_tensors) {
            params.push(client.upload_f32(vals, &meta.shape)?);
        }
        let exe = client.compile_hlo_file(&manifest.artifact_path(dir, "embed")?)?;
        Ok(EmbedExec {
            client: client.clone(),
            cfg: manifest.model.clone(),
            params,
            exe,
        })
    }

    /// Embed a token sequence (truncated/padded to embed_seq) into a unit
    /// vector of dim embed_dim.
    pub fn embed_tokens(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let e = self.cfg.embed_seq;
        let n = tokens.len().min(e);
        let mut padded: Vec<i32> = tokens[..n].iter().map(|&t| t as i32).collect();
        padded.resize(e, 0);
        let tok_buf = self.client.upload_i32(&padded, &[e])?;
        let len_buf = self.client.upload_i32_scalar(n as i32)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let result = self.exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let out = tuple.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// HLO-backed embedder usable wherever the n-gram embedder is (needs a
/// tokenizer to get from text to tokens).
pub struct HloEmbedder {
    exec: std::sync::Arc<EmbedExec>,
    tokenizer: std::sync::Arc<crate::tokenizer::Tokenizer>,
    dim: usize,
}

impl HloEmbedder {
    pub fn new(
        exec: std::sync::Arc<EmbedExec>,
        tokenizer: std::sync::Arc<crate::tokenizer::Tokenizer>,
    ) -> Self {
        let dim = exec.cfg.embed_dim;
        HloEmbedder {
            exec,
            tokenizer,
            dim,
        }
    }
}

impl Embedder for HloEmbedder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn embed(&self, text: &str) -> Vec<f32> {
        let ids = self.tokenizer.encode(text);
        self.exec
            .embed_tokens(&ids)
            .unwrap_or_else(|_| vec![0.0; self.dim])
    }
}

