//! Crate-wide error type.
//!
//! Substrates return `Result<T, Error>`; the binary/examples use `anyhow`
//! at the top level. Variants are grouped by subsystem so integration tests
//! can assert on failure classes (e.g. corruption injection must yield
//! `Error::Corrupt`, never a silent wrong answer).

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    // --- artifacts / runtime ------------------------------------------------
    #[error("artifact missing: {0}")]
    ArtifactMissing(String),
    #[error("manifest invalid: {0}")]
    ManifestInvalid(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),

    // --- serving ------------------------------------------------------------
    #[error("prompt too long: {got} tokens > context window {max}")]
    PromptTooLong { got: usize, max: usize },
    #[error("context window exhausted at position {0}")]
    ContextExhausted(usize),
    #[error("request rejected: {0}")]
    Rejected(String),
    #[error("coordinator shut down")]
    ShutDown,

    // --- persistence ---------------------------------------------------------
    #[error("corrupt cache file: {0}")]
    Corrupt(String),
    #[error("unsupported cache file version {0}")]
    Version(u32),

    // --- parsing -------------------------------------------------------------
    #[error("json error: {0}")]
    Json(String),
    #[error("csv error: {0}")]
    Csv(String),
    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
