//! Crate-wide error type.
//!
//! Substrates return `Result<T, Error>`; the binary/examples surface it at
//! the top level. Variants are grouped by subsystem so integration tests
//! can assert on failure classes (e.g. corruption injection must yield
//! `Error::Corrupt`, never a silent wrong answer). Hand-rolled `Display`
//! because thiserror is not in the offline vendor set.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    // --- artifacts / runtime ------------------------------------------------
    ArtifactMissing(String),
    ManifestInvalid(String),
    Xla(String),
    ShapeMismatch(String),

    // --- serving ------------------------------------------------------------
    PromptTooLong { got: usize, max: usize },
    ContextExhausted(usize),
    /// The paged KV arena ran out of blocks (admission/in-flight pressure).
    ArenaExhausted { needed: usize, free: usize },
    Rejected(String),
    ShutDown,

    // --- persistence ---------------------------------------------------------
    Corrupt(String),
    Version(u32),

    // --- parsing -------------------------------------------------------------
    Json(String),
    Csv(String),
    Config(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ArtifactMissing(s) => write!(f, "artifact missing: {s}"),
            Error::ManifestInvalid(s) => write!(f, "manifest invalid: {s}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::ShapeMismatch(s) => write!(f, "shape mismatch: {s}"),
            Error::PromptTooLong { got, max } => {
                write!(f, "prompt too long: {got} tokens > context window {max}")
            }
            Error::ContextExhausted(pos) => {
                write!(f, "context window exhausted at position {pos}")
            }
            Error::ArenaExhausted { needed, free } => write!(
                f,
                "kv arena exhausted: need {needed} blocks, {free} free"
            ),
            Error::Rejected(s) => write!(f, "request rejected: {s}"),
            Error::ShutDown => write!(f, "coordinator shut down"),
            Error::Corrupt(s) => write!(f, "corrupt cache file: {s}"),
            Error::Version(v) => write!(f, "unsupported cache file version {v}"),
            Error::Json(s) => write!(f, "json error: {s}"),
            Error::Csv(s) => write!(f, "csv error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
