//! Crate-wide error type and failure taxonomy.
//!
//! Substrates return `Result<T, Error>`; the binary/examples surface it at
//! the top level. Variants are grouped by subsystem so integration tests
//! can assert on failure classes (e.g. corruption injection must yield
//! `Error::Corrupt`, never a silent wrong answer). Hand-rolled `Display`
//! because thiserror is not in the offline vendor set.
//!
//! # Failure taxonomy
//!
//! Every variant has a defined class that determines what the serving
//! path does with it ([`Error::is_transient`] is the machine-readable
//! form; the scheduler's retry loop and the TCP front's `error_kind`
//! reply field both key off this table):
//!
//! | variant              | class     | serving-path outcome |
//! |----------------------|-----------|----------------------|
//! | `Xla`                | transient | retried with tick-based backoff up to `transient_retry_limit` attempts |
//! | `Io`                 | transient | retried (model/spill); a failed spill write degrades to drop-on-evict |
//! | `ArenaExhausted`     | transient | shed-and-resume first, then the same bounded retry |
//! | `ShapeMismatch`      | terminal  | request fails immediately with a typed reply |
//! | `PromptTooLong`      | terminal  | rejected at admission |
//! | `ContextExhausted`   | terminal  | request fails; window accounting bug upstream |
//! | `Rejected`           | terminal  | typed reply; never retried |
//! | `Corrupt` / `Version`| terminal  | spill entry dropped, lookup degrades to a clean miss |
//! | `Overloaded`         | shed      | load-shedding reply carrying queue depth/capacity; client may back off and resubmit |
//! | `DeadlineExceeded`   | deadline  | slot reaped at a scheduler tick, reservations freed |
//! | `ShutDown`           | terminal  | coordinator is gone |
//! | `ArtifactMissing` / `ManifestInvalid` / `Json` / `Csv` / `Config` | terminal | startup/parse errors, never on the hot path |
//!
//! Transient means: the operation is safe to re-execute (forward steps
//! are atomic-on-failure per `engine/batch.rs`, spill reads are
//! side-effect free) and the condition is plausibly temporary. Everything
//! else fails fast with a typed reply so clients never hang on a wedged
//! request.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    // --- artifacts / runtime ------------------------------------------------
    ArtifactMissing(String),
    ManifestInvalid(String),
    Xla(String),
    ShapeMismatch(String),

    // --- serving ------------------------------------------------------------
    PromptTooLong { got: usize, max: usize },
    ContextExhausted(usize),
    /// The paged KV arena ran out of blocks (admission/in-flight pressure).
    ArenaExhausted { needed: usize, free: usize },
    Rejected(String),
    /// The request spent longer than its budget in the serving path; the
    /// scheduler reaped the slot and freed its reservations.
    DeadlineExceeded { waited_ms: u64, budget_ms: u64 },
    /// Load shed: a bounded queue was full. Carries the observed depth so
    /// clients can make an informed backoff decision.
    Overloaded { depth: usize, capacity: usize },
    ShutDown,

    // --- persistence ---------------------------------------------------------
    Corrupt(String),
    Version(u32),

    // --- parsing -------------------------------------------------------------
    Json(String),
    Csv(String),
    Config(String),

    Io(std::io::Error),
}

impl Error {
    /// Is this failure class safe and worthwhile to retry? (See the
    /// module-level taxonomy table.) The scheduler's bounded
    /// retry-with-backoff keys off this; everything else fails fast.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Error::Xla(_) | Error::Io(_) | Error::ArenaExhausted { .. }
        )
    }

    /// Stable machine-readable label for the wire protocol's `error_kind`
    /// reply field (one label per variant; clients must not parse the
    /// human-readable message).
    pub fn kind(&self) -> &'static str {
        match self {
            Error::ArtifactMissing(_) => "artifact_missing",
            Error::ManifestInvalid(_) => "manifest_invalid",
            Error::Xla(_) => "backend",
            Error::ShapeMismatch(_) => "shape_mismatch",
            Error::PromptTooLong { .. } => "prompt_too_long",
            Error::ContextExhausted(_) => "context_exhausted",
            Error::ArenaExhausted { .. } => "arena_exhausted",
            Error::Rejected(_) => "rejected",
            Error::DeadlineExceeded { .. } => "deadline_exceeded",
            Error::Overloaded { .. } => "overloaded",
            Error::ShutDown => "shut_down",
            Error::Corrupt(_) => "corrupt",
            Error::Version(_) => "version",
            Error::Json(_) => "json",
            Error::Csv(_) => "csv",
            Error::Config(_) => "config",
            Error::Io(_) => "io",
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ArtifactMissing(s) => write!(f, "artifact missing: {s}"),
            Error::ManifestInvalid(s) => write!(f, "manifest invalid: {s}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::ShapeMismatch(s) => write!(f, "shape mismatch: {s}"),
            Error::PromptTooLong { got, max } => {
                write!(f, "prompt too long: {got} tokens > context window {max}")
            }
            Error::ContextExhausted(pos) => {
                write!(f, "context window exhausted at position {pos}")
            }
            Error::ArenaExhausted { needed, free } => write!(
                f,
                "kv arena exhausted: need {needed} blocks, {free} free"
            ),
            Error::Rejected(s) => write!(f, "request rejected: {s}"),
            Error::DeadlineExceeded { waited_ms, budget_ms } => write!(
                f,
                "deadline exceeded: waited {waited_ms}ms > budget {budget_ms}ms"
            ),
            Error::Overloaded { depth, capacity } => {
                write!(f, "overloaded: queue depth {depth}/{capacity}")
            }
            Error::ShutDown => write!(f, "coordinator shut down"),
            Error::Corrupt(s) => write!(f, "corrupt cache file: {s}"),
            Error::Version(v) => write!(f, "unsupported cache file version {v}"),
            Error::Json(s) => write!(f, "json error: {s}"),
            Error::Csv(s) => write!(f, "csv error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification_matches_taxonomy() {
        assert!(Error::Xla("x".into()).is_transient());
        assert!(Error::Io(std::io::Error::other("x")).is_transient());
        assert!(Error::ArenaExhausted { needed: 1, free: 0 }.is_transient());
        assert!(!Error::ShapeMismatch("x".into()).is_transient());
        assert!(!Error::Corrupt("x".into()).is_transient());
        assert!(!Error::Overloaded { depth: 1, capacity: 1 }.is_transient());
        assert!(!Error::DeadlineExceeded { waited_ms: 1, budget_ms: 1 }.is_transient());
        assert!(!Error::Rejected("x".into()).is_transient());
    }

    #[test]
    fn kinds_are_distinct_labels() {
        let kinds = [
            Error::Xla("x".into()).kind(),
            Error::Overloaded { depth: 0, capacity: 0 }.kind(),
            Error::DeadlineExceeded { waited_ms: 0, budget_ms: 0 }.kind(),
            Error::Corrupt("x".into()).kind(),
            Error::Rejected("x".into()).kind(),
        ];
        let mut uniq = kinds.to_vec();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), kinds.len());
    }

    #[test]
    fn typed_display_for_new_variants() {
        let d = Error::DeadlineExceeded { waited_ms: 55, budget_ms: 30 }.to_string();
        assert!(d.contains("deadline exceeded") && d.contains("55") && d.contains("30"));
        let o = Error::Overloaded { depth: 256, capacity: 256 }.to_string();
        assert!(o.contains("overloaded") && o.contains("256/256"));
    }
}
