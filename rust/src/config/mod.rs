//! Configuration system: model architecture (from the artifact manifest),
//! cache policy, and server tuning. All config is plain JSON parsed with
//! [`crate::util::json`]; every field has a production-sane default so a
//! bare `artifacts/` directory is sufficient to serve.

mod cache;
mod model;
mod server;

pub use cache::{CacheConfig, EvictionPolicy};
pub use model::ModelConfig;
pub use server::{RoutingPolicy, ServerConfig};
