//! Model architecture config — mirrors `python/compile/model.py::ModelConfig`
//! and is read from the `model` section of `artifacts/manifest.json`.

use crate::error::{Error, Result};
use crate::util::json::Value;

/// GPT-2-family architecture hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub vocab_size: usize,
    /// Context window (the paper's fixed 1024-token window for
    /// DialoGPT-medium; 256 for the nano testbed).
    pub max_seq: usize,
    pub d_ff: usize,
    pub head_dim: usize,
    pub embed_dim: usize,
    pub embed_seq: usize,
    /// Prefill chunk buckets with a dedicated HLO executable each.
    pub chunk_sizes: Vec<usize>,
    /// KV sequence-capacity buckets (each (chunk, seq) pair has its own
    /// executable; short live contexts upload and scan less KV).
    pub seq_buckets: Vec<usize>,
    /// End-of-text token id (generation stop).
    pub eot_id: u32,
}

impl ModelConfig {
    /// Parse the `model` object of the manifest.
    pub fn from_json(v: &Value) -> Result<Self> {
        let chunk_sizes = v
            .req_arr("chunk_sizes")?
            .iter()
            .map(|c| {
                c.as_usize()
                    .ok_or_else(|| Error::ManifestInvalid("bad chunk size".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let seq_buckets = v
            .req_arr("seq_buckets")?
            .iter()
            .map(|c| {
                c.as_usize()
                    .ok_or_else(|| Error::ManifestInvalid("bad seq bucket".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let cfg = ModelConfig {
            name: v.req_str("name")?.to_string(),
            n_layer: v.req_usize("n_layer")?,
            n_head: v.req_usize("n_head")?,
            d_model: v.req_usize("d_model")?,
            vocab_size: v.req_usize("vocab_size")?,
            max_seq: v.req_usize("max_seq")?,
            d_ff: v.req_usize("d_ff")?,
            head_dim: v.req_usize("head_dim")?,
            embed_dim: v.req_usize("embed_dim")?,
            embed_seq: v.req_usize("embed_seq")?,
            chunk_sizes,
            seq_buckets,
            eot_id: v.req_usize("eot_id")? as u32,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model != self.n_head * self.head_dim {
            return Err(Error::ManifestInvalid(format!(
                "d_model {} != n_head {} * head_dim {}",
                self.d_model, self.n_head, self.head_dim
            )));
        }
        if self.chunk_sizes.is_empty() {
            return Err(Error::ManifestInvalid("no chunk sizes".into()));
        }
        let mut sorted = self.chunk_sizes.clone();
        sorted.sort();
        if sorted != self.chunk_sizes {
            return Err(Error::ManifestInvalid("chunk_sizes must be ascending".into()));
        }
        if *self.chunk_sizes.last().unwrap() > self.max_seq {
            return Err(Error::ManifestInvalid("chunk larger than context".into()));
        }
        if self.seq_buckets.is_empty()
            || *self.seq_buckets.last().unwrap() != self.max_seq
        {
            return Err(Error::ManifestInvalid(
                "seq_buckets must end at max_seq".into(),
            ));
        }
        let mut sb = self.seq_buckets.clone();
        sb.sort();
        if sb != self.seq_buckets {
            return Err(Error::ManifestInvalid("seq_buckets must be ascending".into()));
        }
        Ok(())
    }

    /// Is an *unpadded* chunk of `c` tokens (`valid_len == c`, not a
    /// compiled bucket) legal at position `cur_len`? Exactly when padding
    /// to the smallest covering bucket would spill past the context
    /// window — the shape `Engine::prefill` emits for the final chunk of
    /// a near-window prompt (the `ForwardModel` contract). Both backends
    /// (MockModel and the PJRT executor) validate against THIS predicate
    /// so their accept/reject behavior cannot diverge. Relies on
    /// `chunk_sizes` being ascending, which `validate` enforces.
    pub fn unpadded_chunk_legal(&self, c: usize, valid_len: usize, cur_len: usize) -> bool {
        c == valid_len
            && cur_len + c <= self.max_seq
            && !self.chunk_sizes.contains(&c)
            && self
                .chunk_sizes
                .iter()
                .find(|&&b| b >= c)
                .is_some_and(|&b| cur_len + b > self.max_seq)
    }

    /// Smallest seq bucket that covers `live` positions (falls back to
    /// max_seq, which validation guarantees is the last bucket).
    pub fn seq_bucket_for(&self, live: usize) -> usize {
        self.seq_buckets
            .iter()
            .copied()
            .find(|&s| s >= live)
            .unwrap_or(self.max_seq)
    }

    /// KV buffer shape `[L, 2, H, S, D]`.
    pub fn kv_shape(&self) -> [usize; 5] {
        [self.n_layer, 2, self.n_head, self.max_seq, self.head_dim]
    }

    /// Elements in one full KV buffer.
    pub fn kv_elems(&self) -> usize {
        self.kv_shape().iter().product()
    }

    /// Bytes of one full (f32) KV buffer — what the cache store accounts.
    pub fn kv_bytes(&self) -> usize {
        4 * self.kv_elems()
    }

    /// Bytes of KV actually *live* for a prefix of `len` tokens
    /// (`[L, 2, H, len, D]`) — what a trimmed cache entry stores.
    pub fn kv_bytes_for_len(&self, len: usize) -> usize {
        4 * self.n_layer * 2 * self.n_head * len * self.head_dim
    }

    /// The nano testbed config (matches the artifact build defaults); used
    /// by unit tests that don't load artifacts.
    pub fn nano() -> Self {
        ModelConfig {
            name: "nano".into(),
            n_layer: 4,
            n_head: 4,
            d_model: 128,
            vocab_size: 512,
            max_seq: 256,
            d_ff: 512,
            head_dim: 32,
            embed_dim: 64,
            embed_seq: 64,
            chunk_sizes: vec![1, 8, 32, 64],
            seq_buckets: vec![64, 128, 256],
            eot_id: 0,
        }
    }

    /// Shape-identical to DialoGPT-medium (the paper's testbed) — used by
    /// the roofline estimator; never served on CPU CI.
    pub fn dialogpt_medium() -> Self {
        ModelConfig {
            name: "dialogpt-medium".into(),
            n_layer: 24,
            n_head: 16,
            d_model: 1024,
            vocab_size: 50257,
            max_seq: 1024,
            d_ff: 4096,
            head_dim: 64,
            embed_dim: 64,
            embed_seq: 64,
            chunk_sizes: vec![1, 8, 32, 64],
            seq_buckets: vec![64, 256, 1024],
            eot_id: 50256,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn nano_is_valid() {
        ModelConfig::nano().validate().unwrap();
        assert_eq!(ModelConfig::nano().kv_shape(), [4, 2, 4, 256, 32]);
        assert_eq!(ModelConfig::nano().kv_bytes(), 4 * 2 * 4 * 256 * 32 * 4);
    }

    #[test]
    fn kv_bytes_for_len_scales_linearly() {
        let c = ModelConfig::nano();
        assert_eq!(c.kv_bytes_for_len(0), 0);
        assert_eq!(c.kv_bytes_for_len(c.max_seq), c.kv_bytes());
        assert_eq!(c.kv_bytes_for_len(10) * 2, c.kv_bytes_for_len(20));
    }

    #[test]
    fn parses_manifest_model_section() {
        let j = r#"{"name":"nano","n_layer":4,"n_head":4,"d_model":128,
                    "vocab_size":512,"max_seq":256,"d_ff":512,"head_dim":32,
                    "embed_dim":64,"embed_seq":64,"chunk_sizes":[1,8,32,64],
                    "seq_buckets":[64,128,256],"eot_id":0}"#;
        let cfg = ModelConfig::from_json(&json::parse(j).unwrap()).unwrap();
        assert_eq!(cfg, ModelConfig::nano());
    }

    #[test]
    fn unpadded_chunk_legality() {
        let mut c = ModelConfig::nano();
        c.chunk_sizes = vec![8, 32, 64]; // min bucket 8
        // near the window (251 + 8 > 256): unpadded 5-chunk legal
        assert!(c.unpadded_chunk_legal(5, 5, 251));
        // mid-window: padding to 8 fits, so the unpadded shape is illegal
        assert!(!c.unpadded_chunk_legal(5, 5, 0));
        // padded (valid_len < c) never qualifies
        assert!(!c.unpadded_chunk_legal(5, 4, 251));
        // a chunk that itself spills past the window is never legal
        assert!(!c.unpadded_chunk_legal(5, 5, 254));
        // an exact bucket is not "unpadded-special"
        assert!(!c.unpadded_chunk_legal(8, 8, 250));
        // larger than every bucket: no covering bucket, not legal
        assert!(!c.unpadded_chunk_legal(100, 100, 200));
    }

    #[test]
    fn rejects_inconsistent_heads() {
        let mut c = ModelConfig::nano();
        c.head_dim = 31;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_unsorted_chunks() {
        let mut c = ModelConfig::nano();
        c.chunk_sizes = vec![8, 1];
        assert!(c.validate().is_err());
        c.chunk_sizes = vec![1, 8, 512];
        assert!(c.validate().is_err());
    }

    #[test]
    fn medium_matches_paper_shape() {
        let c = ModelConfig::dialogpt_medium();
        c.validate().unwrap();
        assert_eq!(c.n_layer, 24);
        assert_eq!(c.d_model, 1024);
        assert_eq!(c.max_seq, 1024);
    }
}
