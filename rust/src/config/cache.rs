//! KV-cache policy configuration.

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Which entry to evict when the store exceeds capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Least recently used (default; matches serving intuition).
    Lru,
    /// Least frequently used, ties broken by recency.
    Lfu,
    /// First in, first out (the paper's implicit append-only behaviour,
    /// bounded).
    Fifo,
    /// Evict the entry with the lowest (hits + 1) * token_len score — an
    /// approximation of "cheapest to recompute, least useful" (cost-aware).
    CostAware,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lru" => Ok(Self::Lru),
            "lfu" => Ok(Self::Lfu),
            "fifo" => Ok(Self::Fifo),
            "cost" | "cost-aware" => Ok(Self::CostAware),
            _ => Err(Error::Config(format!("unknown eviction policy '{s}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Lru => "lru",
            Self::Lfu => "lfu",
            Self::Fifo => "fifo",
            Self::CostAware => "cost-aware",
        }
    }

    pub const ALL: [EvictionPolicy; 4] =
        [Self::Lru, Self::Lfu, Self::Fifo, Self::CostAware];
}

/// KV store sizing + persistence knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Max number of cached prompts (0 = unbounded).
    pub max_entries: usize,
    /// Max total bytes of cached KV (0 = unbounded). Entries are accounted
    /// by their *trimmed* size `kv_bytes_for_len(tokens)`.
    pub max_bytes: usize,
    pub eviction: EvictionPolicy,
    /// Retrieval similarity floor: candidates below this are treated as a
    /// miss before the prefix test even runs (paper uses top-1 retrieval
    /// with no floor; 0.0 reproduces that).
    pub min_similarity: f32,
    /// Compress KV payloads with DEFLATE when persisting to disk.
    pub compress: bool,
    /// Directory for persisted entries (None = RAM only).
    pub persist_dir: Option<String>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            max_entries: 64,
            max_bytes: 0,
            eviction: EvictionPolicy::Lru,
            min_similarity: 0.0,
            compress: false,
            persist_dir: None,
        }
    }
}

impl CacheConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = CacheConfig::default();
        if let Some(x) = v.get("max_entries") {
            c.max_entries = x
                .as_usize()
                .ok_or_else(|| Error::Config("max_entries must be a number".into()))?;
        }
        if let Some(x) = v.get("max_bytes") {
            c.max_bytes = x
                .as_usize()
                .ok_or_else(|| Error::Config("max_bytes must be a number".into()))?;
        }
        if let Some(x) = v.get("eviction") {
            c.eviction = EvictionPolicy::parse(
                x.as_str()
                    .ok_or_else(|| Error::Config("eviction must be a string".into()))?,
            )?;
        }
        if let Some(x) = v.get("min_similarity") {
            c.min_similarity = x
                .as_f64()
                .ok_or_else(|| Error::Config("min_similarity must be a number".into()))?
                as f32;
        }
        if let Some(x) = v.get("compress") {
            c.compress = x
                .as_bool()
                .ok_or_else(|| Error::Config("compress must be a bool".into()))?;
        }
        if let Some(x) = v.get("persist_dir") {
            c.persist_dir = Some(
                x.as_str()
                    .ok_or_else(|| Error::Config("persist_dir must be a string".into()))?
                    .to_string(),
            );
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn defaults() {
        let c = CacheConfig::default();
        assert_eq!(c.eviction, EvictionPolicy::Lru);
        assert_eq!(c.max_entries, 64);
    }

    #[test]
    fn parse_policies() {
        for (s, p) in [
            ("lru", EvictionPolicy::Lru),
            ("lfu", EvictionPolicy::Lfu),
            ("fifo", EvictionPolicy::Fifo),
            ("cost-aware", EvictionPolicy::CostAware),
        ] {
            assert_eq!(EvictionPolicy::parse(s).unwrap(), p);
            assert_eq!(EvictionPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(EvictionPolicy::parse("random").is_err());
    }

    #[test]
    fn from_json_partial_overrides() {
        let v = json::parse(r#"{"max_entries": 3, "eviction": "lfu", "compress": true}"#)
            .unwrap();
        let c = CacheConfig::from_json(&v).unwrap();
        assert_eq!(c.max_entries, 3);
        assert_eq!(c.eviction, EvictionPolicy::Lfu);
        assert!(c.compress);
        assert_eq!(c.min_similarity, 0.0);
    }

    #[test]
    fn from_json_type_errors() {
        let v = json::parse(r#"{"max_entries": "three"}"#).unwrap();
        assert!(CacheConfig::from_json(&v).is_err());
    }
}
