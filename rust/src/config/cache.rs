//! KV-cache policy configuration.

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Which entry to evict when the store exceeds capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Least recently used (default; matches serving intuition).
    Lru,
    /// Least frequently used, ties broken by recency.
    Lfu,
    /// First in, first out (the paper's implicit append-only behaviour,
    /// bounded).
    Fifo,
    /// Evict the entry with the lowest (hits + 1) * token_len score — an
    /// approximation of "cheapest to recompute, least useful" (cost-aware).
    CostAware,
}

impl EvictionPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lru" => Ok(Self::Lru),
            "lfu" => Ok(Self::Lfu),
            "fifo" => Ok(Self::Fifo),
            "cost" | "cost-aware" => Ok(Self::CostAware),
            _ => Err(Error::Config(format!("unknown eviction policy '{s}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Lru => "lru",
            Self::Lfu => "lfu",
            Self::Fifo => "fifo",
            Self::CostAware => "cost-aware",
        }
    }

    pub const ALL: [EvictionPolicy; 4] =
        [Self::Lru, Self::Lfu, Self::Fifo, Self::CostAware];
}

/// KV store sizing + tiering knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Max number of hot (arena-resident) cached prompts (0 = unbounded).
    pub max_entries: usize,
    /// Max *physical* bytes of hot cached KV (0 = unbounded): distinct
    /// arena blocks referenced by cache entries, counted once however
    /// many entries share them — block-granular, shared-aware accounting
    /// (see `kvcache::store`).
    pub max_bytes: usize,
    pub eviction: EvictionPolicy,
    /// Retrieval similarity floor: candidates below this are treated as a
    /// miss before the prefix test even runs (paper uses top-1 retrieval
    /// with no floor; 0.0 reproduces that).
    pub min_similarity: f32,
    /// Legacy (v1) payload-only DEFLATE when persisting/spilling to
    /// disk. Superseded by `spill_compression`, which wins when both are
    /// set; kept so existing configs keep their exact on-disk format.
    pub compress: bool,
    /// Compress spill files with the whole-body DEFLATE (v2) codec, so
    /// `max_spill_bytes` budgets *physical* compressed bytes and the cold
    /// tier holds correspondingly more records within the same budget.
    /// Existing raw (v1) files still reload bit-identically — decoding
    /// dispatches on each file's version header. Off by default: the
    /// on-disk format only changes when asked.
    pub spill_compression: bool,
    /// Keep hot entries quantized (8-bit rows, per-block scales) instead
    /// of f32 arena blocks: resident entries hold **zero** arena blocks
    /// and `max_bytes` budgets their ~4x-smaller quantized footprint,
    /// multiplying hot capacity. A hit dequantizes into a fresh
    /// arena-backed record on attach (small per-hit cost); fidelity is
    /// gated offline by `benches/ablation_spill.rs`'s eval arm. Off by
    /// default: the f32 path is byte-identical to prior behavior.
    pub quantized_blocks: bool,
    /// Directory for persisted entries (None = RAM only).
    pub persist_dir: Option<String>,
    /// Cold-tier (disk spill) budget in serialized bytes. 0 disables
    /// spilling — eviction destroys records (the pre-tier behavior and
    /// the ablation's control arm). > 0 makes eviction *spill* the victim
    /// to disk instead; lookups transparently reload spilled records, and
    /// the tier itself evicts LRU (terminally) past this budget.
    pub max_spill_bytes: usize,
    /// Directory for the cold tier's spill files. None = a fresh unique
    /// directory under the OS temp dir, removed when the store drops; a
    /// configured directory is created if missing and left in place. If
    /// the directory cannot be set up the store logs the error, flags
    /// `CacheStats::spill_setup_failed`, and degrades to drop-on-evict.
    pub spill_dir: Option<String>,
    /// Namespace prefix for this store's spill files (`{ns}{id}.kv`),
    /// opting into **shared-spill semantics**: several stores (one per
    /// serving worker) may point at the same `spill_dir` without their
    /// per-store entry ids colliding on disk, the construction-time
    /// orphan sweep is restricted to this tier's own namespace (it can
    /// never delete a sibling worker's live files), and a lookup miss may
    /// *adopt* a sibling's spilled record whose tokens prefix the new
    /// prompt — cross-worker cache mobility through the cold tier. Keep
    /// it stable across restarts (it is the worker's spill identity, e.g.
    /// `w0_`) so a restarting worker sweeps only its own stale garbage.
    /// Must not end in a digit (namespace+id concatenation stays
    /// unambiguous). Empty (default) = legacy single-store naming; the
    /// store then neither shares nor adopts.
    pub spill_namespace: String,
    /// Segment-tier indexing stride in tokens: every admitted record is
    /// additionally sliced into fixed-stride token spans, each embedded
    /// and indexed independently, so an exact-prefix miss can fall
    /// through to a *segment* match at a different offset (position
    /// re-anchoring at attach; see `recycler`). 0 disables the segment
    /// tier entirely. The stride is the retrieval grain: smaller catches
    /// shorter shared documents but costs more index entries.
    pub segment_tokens: usize,
    /// Per-request fidelity budget for the segment tier: the tolerated
    /// output infidelity (1 - text similarity vs a baseline run, the
    /// `bench/eval.rs` score) of serving through a re-anchored segment.
    /// **0.0 (default) disables segment serving** — the recycler is then
    /// byte-identical to exact-prefix-only, preserving every
    /// token-identity property. > 0 enables the path; the budget is
    /// certified offline by `benches/ablation_segment.rs`, which measures
    /// the segment arm's infidelity against the baseline arm and asserts
    /// it stays within this budget.
    pub segment_fidelity_budget: f64,
    /// Retrieval similarity floor for segment candidates (embedding
    /// cosine between the query window and the indexed span). Stricter
    /// than `min_similarity` by default: a segment hit rewrites KV into a
    /// foreign position, so weak matches must lose to recompute.
    pub segment_min_similarity: f32,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            max_entries: 64,
            max_bytes: 0,
            eviction: EvictionPolicy::Lru,
            min_similarity: 0.0,
            compress: false,
            spill_compression: false,
            quantized_blocks: false,
            persist_dir: None,
            max_spill_bytes: 0,
            spill_dir: None,
            spill_namespace: String::new(),
            segment_tokens: 0,
            segment_fidelity_budget: 0.0,
            segment_min_similarity: 0.80,
        }
    }
}

impl CacheConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = CacheConfig::default();
        if let Some(x) = v.get("max_entries") {
            c.max_entries = x
                .as_usize()
                .ok_or_else(|| Error::Config("max_entries must be a number".into()))?;
        }
        if let Some(x) = v.get("max_bytes") {
            c.max_bytes = x
                .as_usize()
                .ok_or_else(|| Error::Config("max_bytes must be a number".into()))?;
        }
        if let Some(x) = v.get("eviction") {
            c.eviction = EvictionPolicy::parse(
                x.as_str()
                    .ok_or_else(|| Error::Config("eviction must be a string".into()))?,
            )?;
        }
        if let Some(x) = v.get("min_similarity") {
            c.min_similarity = x
                .as_f64()
                .ok_or_else(|| Error::Config("min_similarity must be a number".into()))?
                as f32;
        }
        if let Some(x) = v.get("compress") {
            c.compress = x
                .as_bool()
                .ok_or_else(|| Error::Config("compress must be a bool".into()))?;
        }
        if let Some(x) = v.get("spill_compression") {
            c.spill_compression = x
                .as_bool()
                .ok_or_else(|| Error::Config("spill_compression must be a bool".into()))?;
        }
        if let Some(x) = v.get("quantized_blocks") {
            c.quantized_blocks = x
                .as_bool()
                .ok_or_else(|| Error::Config("quantized_blocks must be a bool".into()))?;
        }
        if let Some(x) = v.get("persist_dir") {
            c.persist_dir = Some(
                x.as_str()
                    .ok_or_else(|| Error::Config("persist_dir must be a string".into()))?
                    .to_string(),
            );
        }
        if let Some(x) = v.get("max_spill_bytes") {
            c.max_spill_bytes = x
                .as_usize()
                .ok_or_else(|| Error::Config("max_spill_bytes must be a number".into()))?;
        }
        if let Some(x) = v.get("spill_dir") {
            c.spill_dir = Some(
                x.as_str()
                    .ok_or_else(|| Error::Config("spill_dir must be a string".into()))?
                    .to_string(),
            );
        }
        if let Some(x) = v.get("spill_namespace") {
            c.spill_namespace = x
                .as_str()
                .ok_or_else(|| Error::Config("spill_namespace must be a string".into()))?
                .to_string();
        }
        if let Some(x) = v.get("segment_tokens") {
            c.segment_tokens = x
                .as_usize()
                .ok_or_else(|| Error::Config("segment_tokens must be a number".into()))?;
        }
        if let Some(x) = v.get("segment_fidelity_budget") {
            c.segment_fidelity_budget = x.as_f64().ok_or_else(|| {
                Error::Config("segment_fidelity_budget must be a number".into())
            })?;
        }
        if let Some(x) = v.get("segment_min_similarity") {
            c.segment_min_similarity = x.as_f64().ok_or_else(|| {
                Error::Config("segment_min_similarity must be a number".into())
            })? as f32;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if !(-1.0..=1.0).contains(&self.min_similarity) {
            // cosine similarity lives in [-1, 1]; anything outside silently
            // disables (or always passes) the retrieval floor
            return Err(Error::Config(format!(
                "min_similarity must be in [-1, 1], got {}",
                self.min_similarity
            )));
        }
        if !(-1.0..=1.0).contains(&self.segment_min_similarity) {
            return Err(Error::Config(format!(
                "segment_min_similarity must be in [-1, 1], got {}",
                self.segment_min_similarity
            )));
        }
        if !(0.0..=1.0).contains(&self.segment_fidelity_budget) {
            // infidelity is 1 - text similarity, which lives in [0, 1]
            return Err(Error::Config(format!(
                "segment_fidelity_budget must be in [0, 1], got {}",
                self.segment_fidelity_budget
            )));
        }
        if self.persist_dir.as_deref() == Some("") {
            return Err(Error::Config("persist_dir must not be empty".into()));
        }
        if self.spill_dir.as_deref() == Some("") {
            return Err(Error::Config("spill_dir must not be empty".into()));
        }
        if !self.spill_namespace.is_empty() {
            if !self
                .spill_namespace
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(Error::Config(format!(
                    "spill_namespace must be [A-Za-z0-9_-], got '{}'",
                    self.spill_namespace
                )));
            }
            if self
                .spill_namespace
                .ends_with(|c: char| c.is_ascii_digit())
            {
                // `{ns}{id}` must parse back unambiguously: "w1" + 23 and
                // "w12" + 3 would both claim "w123.kv"
                return Err(Error::Config(format!(
                    "spill_namespace must not end in a digit, got '{}'",
                    self.spill_namespace
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn defaults() {
        let c = CacheConfig::default();
        assert_eq!(c.eviction, EvictionPolicy::Lru);
        assert_eq!(c.max_entries, 64);
    }

    #[test]
    fn parse_policies() {
        for (s, p) in [
            ("lru", EvictionPolicy::Lru),
            ("lfu", EvictionPolicy::Lfu),
            ("fifo", EvictionPolicy::Fifo),
            ("cost-aware", EvictionPolicy::CostAware),
        ] {
            assert_eq!(EvictionPolicy::parse(s).unwrap(), p);
            assert_eq!(EvictionPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(EvictionPolicy::parse("random").is_err());
    }

    #[test]
    fn from_json_partial_overrides() {
        let v = json::parse(r#"{"max_entries": 3, "eviction": "lfu", "compress": true}"#)
            .unwrap();
        let c = CacheConfig::from_json(&v).unwrap();
        assert_eq!(c.max_entries, 3);
        assert_eq!(c.eviction, EvictionPolicy::Lfu);
        assert!(c.compress);
        assert_eq!(c.min_similarity, 0.0);
        assert_eq!(c.max_spill_bytes, 0, "spilling defaults off");
        assert_eq!(c.spill_dir, None);
    }

    #[test]
    fn from_json_spill_knobs() {
        let v = json::parse(
            r#"{"max_spill_bytes": 1048576, "spill_dir": "/tmp/spill"}"#,
        )
        .unwrap();
        let c = CacheConfig::from_json(&v).unwrap();
        assert_eq!(c.max_spill_bytes, 1 << 20);
        assert_eq!(c.spill_dir.as_deref(), Some("/tmp/spill"));
        let bad = json::parse(r#"{"max_spill_bytes": "lots"}"#).unwrap();
        assert!(CacheConfig::from_json(&bad).is_err());
        let bad = json::parse(r#"{"spill_dir": 3}"#).unwrap();
        assert!(CacheConfig::from_json(&bad).is_err());
    }

    #[test]
    fn from_json_type_errors() {
        let v = json::parse(r#"{"max_entries": "three"}"#).unwrap();
        assert!(CacheConfig::from_json(&v).is_err());
    }

    #[test]
    fn from_json_rejects_invalid_knob_values() {
        // out-of-range or degenerate knob values are typed errors, not
        // silent defaults
        for bad in [
            r#"{"min_similarity": 1.5}"#,
            r#"{"min_similarity": -2.0}"#,
            r#"{"max_entries": -4}"#,
            r#"{"max_spill_bytes": -1}"#,
            r#"{"spill_dir": ""}"#,
            r#"{"persist_dir": ""}"#,
            r#"{"spill_namespace": "w0/"}"#,
            r#"{"spill_namespace": "w1"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let e = CacheConfig::from_json(&v).expect_err(bad);
            assert!(matches!(e, Error::Config(_)), "{bad}: {e}");
        }
        // boundary values are legal
        let v = json::parse(r#"{"min_similarity": -1.0}"#).unwrap();
        assert_eq!(CacheConfig::from_json(&v).unwrap().min_similarity, -1.0);
    }

    #[test]
    fn from_json_segment_knobs() {
        let v = json::parse(
            r#"{"segment_tokens": 16, "segment_fidelity_budget": 0.1,
                "segment_min_similarity": 0.9}"#,
        )
        .unwrap();
        let c = CacheConfig::from_json(&v).unwrap();
        assert_eq!(c.segment_tokens, 16);
        assert_eq!(c.segment_fidelity_budget, 0.1);
        assert_eq!(c.segment_min_similarity, 0.9);
        // defaults: segment tier indexed off, serving gated off
        let d = CacheConfig::default();
        assert_eq!(d.segment_tokens, 0);
        assert_eq!(d.segment_fidelity_budget, 0.0);
        for bad in [
            r#"{"segment_tokens": "many"}"#,
            r#"{"segment_fidelity_budget": 1.5}"#,
            r#"{"segment_fidelity_budget": -0.1}"#,
            r#"{"segment_min_similarity": 2.0}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(CacheConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn from_json_capacity_multiplier_knobs() {
        // both knobs default off: the on-disk and in-arena formats only
        // change when explicitly asked
        let d = CacheConfig::default();
        assert!(!d.spill_compression);
        assert!(!d.quantized_blocks);
        let v = json::parse(
            r#"{"spill_compression": true, "quantized_blocks": true}"#,
        )
        .unwrap();
        let c = CacheConfig::from_json(&v).unwrap();
        assert!(c.spill_compression);
        assert!(c.quantized_blocks);
        for bad in [
            r#"{"spill_compression": "yes"}"#,
            r#"{"spill_compression": 1}"#,
            r#"{"quantized_blocks": "on"}"#,
            r#"{"quantized_blocks": 0}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let e = CacheConfig::from_json(&v).expect_err(bad);
            assert!(matches!(e, Error::Config(_)), "{bad}: {e}");
        }
    }

    #[test]
    fn from_json_spill_namespace() {
        let v = json::parse(r#"{"spill_namespace": "w0_"}"#).unwrap();
        assert_eq!(CacheConfig::from_json(&v).unwrap().spill_namespace, "w0_");
        assert_eq!(CacheConfig::default().spill_namespace, "");
    }
}
