//! Server/coordinator tuning knobs.

use crate::error::{Error, Result};
use crate::util::json::Value;

/// How the router places a request on a worker (`num_workers > 1`).
/// Every policy pins a *session's* later turns to the worker holding its
/// transcript — session stickiness is a correctness requirement, not an
/// optimization; the policy only chooses where sessionless requests and
/// *first* session turns land.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Fingerprint the prompt's leading bytes and stick each prefix
    /// family to one worker, so repeats and extensions of a prompt land
    /// where its KV blocks are already hot; falls back to least-loaded
    /// when the affine worker's queue is saturated (sessionless requests
    /// only). The default, and the configuration the paper's recycling
    /// thesis needs at scale.
    #[default]
    PrefixAffinity,
    /// Rotate across workers — the cache-oblivious ablation baseline.
    RoundRobin,
    /// Send to the shallowest queue — the load-only ablation baseline.
    LeastLoaded,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "prefix-affinity" | "affinity" => Ok(Self::PrefixAffinity),
            "round-robin" | "rr" => Ok(Self::RoundRobin),
            "least-loaded" | "ll" => Ok(Self::LeastLoaded),
            _ => Err(Error::Config(format!("unknown routing policy '{s}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::PrefixAffinity => "prefix-affinity",
            Self::RoundRobin => "round-robin",
            Self::LeastLoaded => "least-loaded",
        }
    }

    pub const ALL: [RoutingPolicy; 3] =
        [Self::PrefixAffinity, Self::RoundRobin, Self::LeastLoaded];
}

/// Coordinator + TCP server configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// TCP listen address for `server::tcp`.
    pub listen: String,
    /// Request queue capacity; submissions beyond this are rejected
    /// (backpressure, paper-agnostic serving hygiene).
    pub queue_capacity: usize,
    /// Max requests drained per scheduling tick (the "batch" — the paper
    /// fixes batch size 1; larger values amortize queue overhead while the
    /// engine still executes sequentially on the single-stream runtime).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub batch_window_ms: u64,
    /// How long an *idle* scheduler blocks waiting for the first request of
    /// a batch before re-checking shutdown (was hardcoded to 50 ms). Only
    /// affects idle-loop wakeup latency — while streams are decoding,
    /// admission is non-blocking.
    pub batch_first_wait_ms: u64,
    /// Default max_new_tokens when a request does not specify one.
    pub default_max_new_tokens: usize,
    /// Whether new prompts are inserted into the KV cache after prefill
    /// (true = the paper's cache-building pass happens online).
    pub populate_cache: bool,
    /// Per-tick token budget for chunked prefill: each scheduler tick
    /// advances an admitting slot's prefill by at most this many prompt
    /// tokens alongside the batched decode dispatch, so one long
    /// cache-cold prompt cannot stall in-flight decode streams for more
    /// than a chunk's worth of work (head-of-line bound). Values at or
    /// above the context window reproduce the old inline-at-admission
    /// behavior (the whole prefill runs in the admission tick).
    pub prefill_chunk_tokens: usize,
    /// How many slots may be in the chunked-prefill state at once;
    /// arrivals beyond this are held back until a prefill completes. The
    /// per-tick prefill work is bounded by
    /// `prefill_chunk_tokens * max_prefilling_slots`.
    pub max_prefilling_slots: usize,
    /// Per-request wall-clock deadline, enforced at scheduler ticks: a
    /// request (queued, deferred, or running) older than this is reaped —
    /// its slot frees every block reservation and the client receives a
    /// typed `Error::DeadlineExceeded` instead of hanging forever.
    pub request_timeout_ms: u64,
    /// Total attempts for an operation that hits a *transient* fault
    /// (`Error::is_transient`): 1 = fail fast, the default 3 = the first
    /// try plus two retries. Terminal errors never retry.
    pub transient_retry_limit: usize,
    /// Base backoff between transient retries, measured in scheduler
    /// ticks (no wall-clock sleeps on the worker thread): retry k waits
    /// `retry_backoff_ticks << k` ticks while the rest of the batch keeps
    /// decoding.
    pub retry_backoff_ticks: usize,
    /// How many scheduler workers the coordinator shards requests over.
    /// Each worker owns a full `Scheduler` + arena + recycler stack;
    /// `queue_capacity`, cache, and arena budgets are all per worker.
    /// 1 (the default) reproduces the single-scheduler coordinator
    /// exactly — same thread layout, same stats, same behavior.
    pub num_workers: usize,
    /// Placement policy the router uses at `num_workers > 1` (ignored at
    /// 1, where every request lands on the only worker).
    pub routing: RoutingPolicy,
    /// Serving-level override of the recycler's segment-tier fidelity
    /// budget (`CacheConfig::segment_fidelity_budget`), applied by
    /// `Scheduler::new` the way `populate_cache` is. `None` (default)
    /// leaves the recycler's own cache config authoritative; `Some(0.0)`
    /// forces exact-only serving cluster-wide regardless of how each
    /// worker's cache was built.
    pub segment_fidelity_budget: Option<f64>,
    /// Per-tenant bounded queue depth in the streaming front's QoS layer;
    /// a tenant whose queue is full gets an immediate typed `Overloaded`
    /// event instead of unbounded buffering.
    pub tenant_queue_capacity: usize,
    /// Weighted deficit round-robin quantum, in generation tokens: each
    /// pass credits a tenant `quantum * weight` tokens of deficit and
    /// dispatches requests while the deficit covers their `max_new_tokens`
    /// cost. Larger values trade fairness granularity for batching.
    pub qos_quantum_tokens: usize,
    /// Weight for tenants not listed in `tenant_weights` (and for the
    /// anonymous tenant).
    pub qos_default_weight: usize,
    /// Per-tenant WDRR weights: a tenant with weight 2 gets twice the
    /// fair-share goodput of a weight-1 tenant under contention.
    pub tenant_weights: Vec<(String, usize)>,
    /// Overload-shedding gate on the live scheduler queue-wait signal: when
    /// > 0 and the recent average queue wait (from successive
    /// `CoordinatorStats::scheduler` snapshots) exceeds this, the QoS pump
    /// sheds new arrivals with a typed `Overloaded` event instead of
    /// queueing them. 0 (default) disables the wait-based gate; shedding
    /// then happens only on full tenant queues / downstream backpressure.
    pub qos_shed_wait_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:7077".into(),
            queue_capacity: 256,
            max_batch: 8,
            batch_window_ms: 2,
            batch_first_wait_ms: 50,
            default_max_new_tokens: 32,
            populate_cache: true,
            prefill_chunk_tokens: 32,
            max_prefilling_slots: 1,
            request_timeout_ms: 30_000,
            transient_retry_limit: 3,
            retry_backoff_ticks: 1,
            num_workers: 1,
            routing: RoutingPolicy::PrefixAffinity,
            segment_fidelity_budget: None,
            tenant_queue_capacity: 64,
            qos_quantum_tokens: 8,
            qos_default_weight: 1,
            tenant_weights: Vec::new(),
            qos_shed_wait_ms: 0,
        }
    }
}

impl ServerConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = ServerConfig::default();
        if let Some(x) = v.get("listen") {
            c.listen = x
                .as_str()
                .ok_or_else(|| Error::Config("listen must be a string".into()))?
                .to_string();
        }
        let usize_field = |field: &str| -> Result<Option<usize>> {
            match v.get(field) {
                None => Ok(None),
                Some(x) => x
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| Error::Config(format!("{field} must be a number"))),
            }
        };
        if let Some(n) = usize_field("queue_capacity")? {
            c.queue_capacity = n;
        }
        if let Some(n) = usize_field("max_batch")? {
            c.max_batch = n;
        }
        if let Some(n) = usize_field("default_max_new_tokens")? {
            c.default_max_new_tokens = n;
        }
        if let Some(n) = usize_field("prefill_chunk_tokens")? {
            c.prefill_chunk_tokens = n;
        }
        if let Some(n) = usize_field("max_prefilling_slots")? {
            c.max_prefilling_slots = n;
        }
        if let Some(n) = usize_field("request_timeout_ms")? {
            c.request_timeout_ms = n as u64;
        }
        if let Some(n) = usize_field("transient_retry_limit")? {
            c.transient_retry_limit = n;
        }
        if let Some(n) = usize_field("retry_backoff_ticks")? {
            c.retry_backoff_ticks = n;
        }
        if let Some(n) = usize_field("num_workers")? {
            c.num_workers = n;
        }
        if let Some(x) = v.get("routing") {
            c.routing = RoutingPolicy::parse(
                x.as_str()
                    .ok_or_else(|| Error::Config("routing must be a string".into()))?,
            )?;
        }
        if let Some(x) = v.get("batch_window_ms") {
            c.batch_window_ms = x
                .as_usize()
                .ok_or_else(|| Error::Config("batch_window_ms must be a number".into()))?
                as u64;
        }
        if let Some(x) = v.get("batch_first_wait_ms") {
            c.batch_first_wait_ms = x
                .as_usize()
                .ok_or_else(|| Error::Config("batch_first_wait_ms must be a number".into()))?
                as u64;
        }
        if let Some(x) = v.get("populate_cache") {
            c.populate_cache = x
                .as_bool()
                .ok_or_else(|| Error::Config("populate_cache must be a bool".into()))?;
        }
        if let Some(x) = v.get("segment_fidelity_budget") {
            c.segment_fidelity_budget = Some(x.as_f64().ok_or_else(|| {
                Error::Config("segment_fidelity_budget must be a number".into())
            })?);
        }
        if let Some(n) = usize_field("tenant_queue_capacity")? {
            c.tenant_queue_capacity = n;
        }
        if let Some(n) = usize_field("qos_quantum_tokens")? {
            c.qos_quantum_tokens = n;
        }
        if let Some(n) = usize_field("qos_default_weight")? {
            c.qos_default_weight = n;
        }
        if let Some(n) = usize_field("qos_shed_wait_ms")? {
            c.qos_shed_wait_ms = n as u64;
        }
        if let Some(x) = v.get("tenant_weights") {
            let Value::Obj(entries) = x else {
                return Err(Error::Config(
                    "tenant_weights must be an object of tenant -> weight".into(),
                ));
            };
            c.tenant_weights = entries
                .iter()
                .map(|(k, w)| {
                    w.as_usize()
                        .map(|w| (k.clone(), w))
                        .ok_or_else(|| {
                            Error::Config(format!("tenant_weights[{k}] must be a number"))
                        })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 || self.queue_capacity == 0 {
            return Err(Error::Config("max_batch/queue_capacity must be > 0".into()));
        }
        if self.batch_first_wait_ms == 0 {
            // the idle scheduler blocks for this long between queue polls;
            // zero would busy-spin a core whenever the server is idle
            return Err(Error::Config("batch_first_wait_ms must be > 0".into()));
        }
        if self.prefill_chunk_tokens == 0 || self.max_prefilling_slots == 0 {
            // zero budget/slots would wedge admission: prefill could never
            // advance, so no request would ever reach decode
            return Err(Error::Config(
                "prefill_chunk_tokens/max_prefilling_slots must be > 0".into(),
            ));
        }
        if self.request_timeout_ms == 0 {
            // a zero deadline would reap every request at its first tick
            return Err(Error::Config("request_timeout_ms must be > 0".into()));
        }
        if self.transient_retry_limit == 0 {
            // zero attempts is meaningless; 1 = fail fast
            return Err(Error::Config("transient_retry_limit must be >= 1".into()));
        }
        if self.retry_backoff_ticks == 0 {
            // a zero base backoff would re-fire the faulty operation in the
            // same tick it failed, defeating the point of backing off
            return Err(Error::Config("retry_backoff_ticks must be >= 1".into()));
        }
        if self.num_workers == 0 {
            // zero workers means no scheduler thread: nothing could ever
            // serve a request
            return Err(Error::Config("num_workers must be >= 1".into()));
        }
        if let Some(b) = self.segment_fidelity_budget {
            if !(0.0..=1.0).contains(&b) {
                // infidelity is 1 - text similarity, which lives in [0, 1]
                return Err(Error::Config(format!(
                    "segment_fidelity_budget must be in [0, 1], got {b}"
                )));
            }
        }
        if self.tenant_queue_capacity == 0 {
            // a zero-depth tenant queue would shed every streamed request
            return Err(Error::Config("tenant_queue_capacity must be >= 1".into()));
        }
        if self.qos_quantum_tokens == 0 || self.qos_default_weight == 0 {
            // a zero quantum or weight would never accumulate deficit, so
            // the WDRR pump could never dispatch that tenant's requests
            return Err(Error::Config(
                "qos_quantum_tokens/qos_default_weight must be >= 1".into(),
            ));
        }
        for (tenant, w) in &self.tenant_weights {
            if *w == 0 {
                return Err(Error::Config(format!(
                    "tenant_weights[{tenant}] must be >= 1 (zero would starve the tenant)"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn defaults_valid() {
        ServerConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_overrides() {
        let v = json::parse(
            r#"{"listen": "0.0.0.0:9", "max_batch": 4, "populate_cache": false,
                "batch_first_wait_ms": 7}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.listen, "0.0.0.0:9");
        assert_eq!(c.max_batch, 4);
        assert!(!c.populate_cache);
        assert_eq!(c.queue_capacity, 256);
        assert_eq!(c.batch_first_wait_ms, 7);
    }

    #[test]
    fn first_wait_defaults_to_legacy_50ms() {
        assert_eq!(ServerConfig::default().batch_first_wait_ms, 50);
        let v = json::parse(r#"{"batch_first_wait_ms": "no"}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
    }

    #[test]
    fn rejects_zero_first_wait() {
        // zero would busy-spin the idle worker loop
        let v = json::parse(r#"{"batch_first_wait_ms": 0}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
    }

    #[test]
    fn rejects_zero_batch() {
        let v = json::parse(r#"{"max_batch": 0}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
    }

    #[test]
    fn parses_chunked_prefill_knobs() {
        let v = json::parse(
            r#"{"prefill_chunk_tokens": 16, "max_prefilling_slots": 2}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.prefill_chunk_tokens, 16);
        assert_eq!(c.max_prefilling_slots, 2);
        // defaults: one admitting slot, bucket-sized budget
        let d = ServerConfig::default();
        assert_eq!(d.prefill_chunk_tokens, 32);
        assert_eq!(d.max_prefilling_slots, 1);
    }

    #[test]
    fn parses_failure_handling_knobs() {
        let v = json::parse(
            r#"{"request_timeout_ms": 1500, "transient_retry_limit": 5,
                "retry_backoff_ticks": 2}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.request_timeout_ms, 1500);
        assert_eq!(c.transient_retry_limit, 5);
        assert_eq!(c.retry_backoff_ticks, 2);
        let d = ServerConfig::default();
        assert_eq!(d.request_timeout_ms, 30_000);
        assert_eq!(d.transient_retry_limit, 3);
        assert_eq!(d.retry_backoff_ticks, 1);
    }

    #[test]
    fn rejects_invalid_failure_handling_knobs() {
        // zero/negative/non-numeric knob values must be typed errors, not
        // silent defaults
        for bad in [
            r#"{"request_timeout_ms": 0}"#,
            r#"{"request_timeout_ms": -5}"#,
            r#"{"request_timeout_ms": "soon"}"#,
            r#"{"transient_retry_limit": 0}"#,
            r#"{"transient_retry_limit": -1}"#,
            r#"{"retry_backoff_ticks": 0}"#,
            r#"{"retry_backoff_ticks": -2}"#,
            r#"{"queue_capacity": -1}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let e = ServerConfig::from_json(&v).expect_err(bad);
            assert!(matches!(e, Error::Config(_)), "{bad}: {e}");
        }
    }

    #[test]
    fn parses_sharding_knobs() {
        let v = json::parse(r#"{"num_workers": 4, "routing": "round-robin"}"#).unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.num_workers, 4);
        assert_eq!(c.routing, RoutingPolicy::RoundRobin);
        // defaults: single worker, prefix-affinity placement
        let d = ServerConfig::default();
        assert_eq!(d.num_workers, 1);
        assert_eq!(d.routing, RoutingPolicy::PrefixAffinity);
        for (s, p) in [
            ("prefix-affinity", RoutingPolicy::PrefixAffinity),
            ("affinity", RoutingPolicy::PrefixAffinity),
            ("rr", RoutingPolicy::RoundRobin),
            ("least-loaded", RoutingPolicy::LeastLoaded),
            ("ll", RoutingPolicy::LeastLoaded),
        ] {
            assert_eq!(RoutingPolicy::parse(s).unwrap(), p);
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
        for bad in [
            r#"{"num_workers": 0}"#,
            r#"{"num_workers": -2}"#,
            r#"{"routing": "random"}"#,
            r#"{"routing": 3}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn parses_segment_budget_override() {
        let v = json::parse(r#"{"segment_fidelity_budget": 0.05}"#).unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.segment_fidelity_budget, Some(0.05));
        // default: no override, the recycler's cache config stands
        assert_eq!(ServerConfig::default().segment_fidelity_budget, None);
        for bad in [
            r#"{"segment_fidelity_budget": 1.5}"#,
            r#"{"segment_fidelity_budget": -0.1}"#,
            r#"{"segment_fidelity_budget": "small"}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn parses_qos_knobs() {
        let v = json::parse(
            r#"{"tenant_queue_capacity": 8, "qos_quantum_tokens": 16,
                "qos_default_weight": 2, "qos_shed_wait_ms": 250,
                "tenant_weights": {"gold": 4, "free": 1}}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.tenant_queue_capacity, 8);
        assert_eq!(c.qos_quantum_tokens, 16);
        assert_eq!(c.qos_default_weight, 2);
        assert_eq!(c.qos_shed_wait_ms, 250);
        assert_eq!(
            c.tenant_weights,
            vec![("gold".to_string(), 4), ("free".to_string(), 1)]
        );
        // defaults: fair single-weight tenants, wait-based shedding off
        let d = ServerConfig::default();
        assert_eq!(d.tenant_queue_capacity, 64);
        assert_eq!(d.qos_quantum_tokens, 8);
        assert_eq!(d.qos_default_weight, 1);
        assert!(d.tenant_weights.is_empty());
        assert_eq!(d.qos_shed_wait_ms, 0);
    }

    #[test]
    fn rejects_invalid_qos_knobs() {
        for bad in [
            r#"{"tenant_queue_capacity": 0}"#,
            r#"{"qos_quantum_tokens": 0}"#,
            r#"{"qos_default_weight": 0}"#,
            r#"{"qos_shed_wait_ms": "soon"}"#,
            r#"{"tenant_weights": {"gold": 0}}"#,
            r#"{"tenant_weights": {"gold": "heavy"}}"#,
            r#"{"tenant_weights": [1, 2]}"#,
        ] {
            let v = json::parse(bad).unwrap();
            let e = ServerConfig::from_json(&v).expect_err(bad);
            assert!(matches!(e, Error::Config(_)), "{bad}: {e}");
        }
    }

    #[test]
    fn rejects_zero_prefill_knobs() {
        // zero budget or zero slots would wedge admission forever
        let v = json::parse(r#"{"prefill_chunk_tokens": 0}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"max_prefilling_slots": 0}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
    }
}
