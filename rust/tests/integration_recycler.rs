//! Cross-module integration: recycler + persistence + eviction + policies,
//! on the mock model (no artifacts needed), plus the evaluation harness
//! end-to-end.

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::bench::{overlap_workload, run_comparison, EvalOptions, OverlapSpec};
use recycle_serve::config::{CacheConfig, EvictionPolicy, ModelConfig};
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::persist;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::{MockModel, TempDir};
use recycle_serve::tokenizer::Tokenizer;

fn mk_recycler(policy: RecyclePolicy, cache: CacheConfig) -> Recycler<MockModel> {
    Recycler::new(
        Engine::new(MockModel::new(ModelConfig::nano())),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(128)),
        cache,
        policy,
    )
}

#[test]
fn kv_record_survives_disk_roundtrip_and_still_recycles() {
    // Cache a prompt, persist its record, reload it, inject it into a fresh
    // engine: the recycled generation must still equal baseline.
    let dir = std::env::temp_dir().join("recycle_serve_it_persist");
    std::fs::create_dir_all(&dir).unwrap();

    let cache_text = "what is the capital of france?";
    let test_text = "what is the capital of france? and of italy?";

    let mut r1 = mk_recycler(RecyclePolicy::Strict, CacheConfig::default());
    let id = r1.insert_prompt(cache_text).unwrap();
    let rec = r1.store().peek(id).unwrap();
    let path = dir.join("entry.kv");
    for compress in [false, true] {
        persist::save(&rec, &path, compress).unwrap();

        // Recycle from the *loaded* record through a fresh engine (and a
        // fresh arena — the record materializes into it on load).
        let mut engine = Engine::new(MockModel::new(ModelConfig::nano()));
        let loaded = persist::load(&path, engine.arena()).unwrap();
        assert_eq!(loaded.tokens, rec.tokens);
        assert_eq!(loaded.kv.to_contiguous(), rec.kv.to_contiguous());

        let tok = Tokenizer::new(vec![]);
        let test_ids = tok.encode(test_text);
        let base = engine
            .generate(&test_ids, engine.empty_kv(), 0, 6, false)
            .unwrap();
        let kv = loaded.attach(); // zero-copy injection of the loaded entry
        let rec_out = engine
            .generate(&test_ids, kv, loaded.token_len(), 6, false)
            .unwrap();
        assert_eq!(rec_out.ids, base.ids, "compress={compress}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_cache_file_fails_loudly() {
    let dir = std::env::temp_dir().join("recycle_serve_it_corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut r = mk_recycler(RecyclePolicy::Strict, CacheConfig::default());
    let id = r.insert_prompt("some cached prompt text").unwrap();
    let rec = r.store().peek(id).unwrap();
    let path = dir.join("e.kv");
    persist::save(&rec, &path, true).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    bytes[n / 2] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    assert!(persist::load(&path, r.arena()).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn spilled_record_serves_prefix_hit_token_identical_to_unevicted() {
    // Acceptance: a lookup whose record was spilled under pressure still
    // returns a prefix hit, with output tokens identical to the
    // never-evicted run, and spill_hits > 0 in CacheStats.
    let cache_text = "what is the capital of france?";
    let other_text = "how do rockets launch into orbit today?";
    let test_text = "what is the capital of france? also name a nearby town.";

    // arm 1: the record never leaves the hot tier
    let mut a = mk_recycler(RecyclePolicy::Strict, CacheConfig::default());
    a.populate_cache = false;
    a.warm(&[cache_text]).unwrap();
    let want = a.generate(test_text, 6).unwrap();
    assert!(want.cache_hit, "reference arm must hit");

    // arm 2: max_entries 1 forces the record through the cold tier
    let tmp = TempDir::new("it_spill");
    let mut b = mk_recycler(
        RecyclePolicy::Strict,
        CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_dir: Some(tmp.path_string()),
            ..Default::default()
        },
    );
    b.populate_cache = false;
    b.warm(&[cache_text]).unwrap();
    b.warm(&[other_text]).unwrap(); // evicts cache_text -> spilled to disk
    assert_eq!(b.store().len(), 1);
    assert_eq!(b.store().spilled_len(), 1, "eviction must spill, not drop");
    assert!(b.store().cold_bytes() > 0);

    let got = b.generate(test_text, 6).unwrap();
    assert!(got.cache_hit, "spilled record must still serve a prefix hit");
    assert_eq!(got.reuse_depth, want.reuse_depth);
    assert_eq!(got.ids, want.ids, "token-identical to the never-evicted run");
    assert_eq!(got.text, want.text);
    let s = b.store().stats();
    assert!(s.spill_hits > 0, "reload must be counted: {s:?}");
    assert!(s.spills >= 1);
    assert_eq!(s.spill_load_errors, 0);
}

#[test]
fn corrupt_spill_file_is_a_typed_miss_not_garbage() {
    // A bit-flipped spill file must surface as a recorded load error and a
    // clean cache miss (baseline-identical output) — never as garbage KV
    // injected into the arena.
    let cache_text = "what is the capital of france?";
    let other_text = "how do rockets launch into orbit today?";
    let test_text = "what is the capital of france? also name a nearby town.";

    let tmp = TempDir::new("it_corrupt_spill");
    let mut r = mk_recycler(
        RecyclePolicy::Strict,
        CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_dir: Some(tmp.path_string()),
            ..Default::default()
        },
    );
    r.populate_cache = false;
    r.warm(&[cache_text]).unwrap();
    r.warm(&[other_text]).unwrap(); // cache_text -> spilled
    assert_eq!(r.store().spilled_len(), 1);

    // flip one bit of the (single) spill file on disk
    let file = std::fs::read_dir(tmp.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "kv"))
        .expect("one spill file on disk");
    let mut bytes = std::fs::read(&file).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x08;
    std::fs::write(&file, &bytes).unwrap();

    let mut base = mk_recycler(RecyclePolicy::Off, CacheConfig::default());
    let want = base.generate(test_text, 5).unwrap();
    let got = r.generate(test_text, 5).unwrap();
    assert!(!got.cache_hit, "corrupt reload must degrade to a miss");
    assert_eq!(got.ids, want.ids, "miss path serves baseline tokens");
    let s = r.store().stats();
    assert_eq!(s.spill_load_errors, 1, "typed load error recorded: {s:?}");
    assert_eq!(s.spill_hits, 0);
    assert_eq!(r.store().spilled_len(), 0, "dead cold entry dropped");
}

#[test]
fn all_eviction_policies_keep_recycler_consistent() {
    for policy in EvictionPolicy::ALL {
        let mut r = mk_recycler(
            RecyclePolicy::Strict,
            CacheConfig {
                max_entries: 3,
                eviction: policy,
                ..Default::default()
            },
        );
        r.populate_cache = false;
        // stream 12 distinct prompts through the cache
        for i in 0..12 {
            r.insert_prompt(&format!("prompt number {i} about topic {}", i * 7))
                .unwrap();
        }
        assert_eq!(r.cache_len(), 3, "{policy:?}");
        // a hit on a surviving entry still works
        let survivors: Vec<String> = r
            .store()
            .iter()
            .map(|(_, rec)| rec.text.clone())
            .collect();
        let extended = format!("{} with extra words", survivors[0]);
        let out = r.generate(&extended, 3).unwrap();
        assert!(out.cache_hit, "{policy:?}");
    }
}

#[test]
fn strict_and_radix_agree_on_paper_workload() {
    // On exact-prefix workloads, radix must find at least the strict hit.
    let w = overlap_workload(OverlapSpec {
        pairs: 6,
        prefix_words: 10,
        suffix_words: 4,
        miss_rate: 0.0,
        seed: 11,
    });
    let cache_refs: Vec<&str> = w.cache_prompts.iter().map(|s| s.as_str()).collect();

    let mut strict = mk_recycler(RecyclePolicy::Strict, CacheConfig::default());
    strict.populate_cache = false;
    strict.warm(&cache_refs).unwrap();

    let mut radix = mk_recycler(RecyclePolicy::Radix, CacheConfig::default());
    radix.populate_cache = false;
    radix.warm(&cache_refs).unwrap();

    for p in &w.test_prompts {
        let s = strict.generate(p, 4).unwrap();
        let r = radix.generate(p, 4).unwrap();
        assert!(s.cache_hit && r.cache_hit, "{p}");
        assert!(r.reuse_depth >= s.reuse_depth);
        assert_eq!(s.ids, r.ids, "outputs must agree regardless of policy");
    }
}

#[test]
fn radix_beats_strict_on_partial_overlap() {
    // When the retrieval candidate diverges but a shorter cached prefix
    // exists, strict misses and radix still recycles.
    let mut strict = mk_recycler(RecyclePolicy::Strict, CacheConfig::default());
    let mut radix = mk_recycler(RecyclePolicy::Radix, CacheConfig::default());
    for r in [&mut strict, &mut radix] {
        r.populate_cache = false;
        // entry A: near-duplicate of the query but diverging at byte 0 (wins
        // embedding retrieval, fails the prefix test); entry B: a short true
        // prefix (loses retrieval, but the radix tree finds it).
        r.warm(&[
            "a quick brown cat sleeps near the river bank today quietly",
            "the quick",
        ])
        .unwrap();
    }
    let q = "the quick brown cat sleeps near the river bank today";
    let s = strict.generate(q, 3).unwrap();
    let r = radix.generate(q, 3).unwrap();
    assert!(!s.cache_hit, "strict candidate diverges -> miss");
    assert!(r.cache_hit, "radix finds 'the quick brown'");
    assert_eq!(s.ids, r.ids, "fidelity holds either way");
}

#[test]
fn eval_harness_full_protocol_with_delay_model() {
    let w = overlap_workload(OverlapSpec {
        pairs: 8,
        prefix_words: 14,
        suffix_words: 4,
        miss_rate: 0.25,
        seed: 42,
    });
    let tok = Arc::new(Tokenizer::new(vec![]));
    let opts = EvalOptions {
        max_new_tokens: 4,
        ..Default::default()
    };
    let report = run_comparison(
        || MockModel::with_delay(ModelConfig::nano(), Duration::from_micros(150)),
        tok,
        &w,
        &opts,
    )
    .unwrap();
    let c = &report.comparison;
    assert_eq!(c.total_prompts, 8);
    assert!(c.cache_hits >= 4 && c.cache_hits < 8, "hits={}", c.cache_hits);
    // hits are faster
    let (hit_s, _miss_s) = c.avg_speedup_split(&report.recycled_rows);
    assert!(hit_s > 10.0, "hit speedup {hit_s}%");
    // fidelity: all outputs identical under greedy decoding
    assert!(c.avg_output_similarity() > 0.999);
}

#[test]
fn min_similarity_floor_gates_retrieval() {
    let mut r = mk_recycler(
        RecyclePolicy::Strict,
        CacheConfig {
            min_similarity: 0.99,
            ..Default::default()
        },
    );
    r.populate_cache = false;
    r.warm(&["alpha beta gamma delta"]).unwrap();
    // extension has high-but-not-0.99 similarity -> gated off
    let out = r
        .generate("alpha beta gamma delta epsilon zeta eta theta iota kappa", 3)
        .unwrap();
    assert!(!out.cache_hit);
    // identical prompt passes the floor
    let out2 = r.generate("alpha beta gamma delta", 3).unwrap();
    assert!(out2.cache_hit);
}
