//! Protocol conformance for the streaming network front: frame grammar,
//! per-connection interleaving, typed mid-stream failures, half-close /
//! disconnect resource release, per-tenant QoS counters and shedding —
//! all over real sockets against the nonblocking event loop, plus a
//! socket-chaos property (`ClientStall` / `TornClientWrite`) asserting
//! the stream contract survives adversarial client I/O.
//!
//! Resource-release assertions poll the wire-visible stats (arena used
//! blocks, front queue depth, inflight count) with a deadline rather
//! than asserting one snapshot: workers publish stats at tick
//! granularity, so a terminal frame — sent mid-tick — can race a stale
//! snapshot by design. Stacks under conservation asserts run with
//! `populate_cache: false` so completed requests hold no cache blocks.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use recycle_serve::config::{ModelConfig, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::faults::{FaultHandle, FaultPlan, FaultSite};
use recycle_serve::index::NgramEmbedder;
use recycle_serve::prop_assert;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::server::{Server, TcpClient};
use recycle_serve::testutil::prop::{check, text};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::json::{self, Value};

/// Worker count for the shared stack (CI reruns the suite at
/// `RECYCLE_NUM_WORKERS=4` to cover the sharded router path).
fn num_workers_from_env() -> usize {
    std::env::var("RECYCLE_NUM_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Mock-backed stack with an optional per-token decode delay (to keep
/// streams open long enough to interact with mid-flight) and a fault
/// handle armed at the front's client seams.
fn spawn_stack_opts(
    cfg: ServerConfig,
    per_token: Option<Duration>,
    faults: FaultHandle,
) -> (Arc<Coordinator>, Server) {
    let coordinator = Arc::new(Coordinator::spawn(
        move |_worker| {
            let model = match per_token {
                Some(d) => MockModel::with_delay(ModelConfig::nano(), d),
                None => MockModel::new(ModelConfig::nano()),
            };
            Recycler::new(
                Engine::new(model),
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                Default::default(),
                RecyclePolicy::Strict,
            )
        },
        cfg,
    ));
    let server =
        Server::start_with_faults(Arc::clone(&coordinator), "127.0.0.1:0", faults).unwrap();
    (coordinator, server)
}

fn spawn_stack_with(cfg: ServerConfig) -> (Arc<Coordinator>, Server) {
    spawn_stack_opts(cfg, None, FaultHandle::off())
}

/// Default stack for conservation-asserting tests: cache admission off,
/// so arena blocks drain to zero once every request has completed.
fn drainable_cfg() -> ServerConfig {
    ServerConfig {
        num_workers: num_workers_from_env(),
        populate_cache: false,
        ..Default::default()
    }
}

/// One streaming request line with an explicit client request id.
fn stream_line(rid: usize, prompt: &str, max_new: usize, tenant: Option<&str>) -> String {
    let mut fields = vec![
        ("prompt", json::s(prompt)),
        ("max_new_tokens", json::n(max_new as f64)),
        ("stream", json::b(true)),
        ("rid", json::n(rid as f64)),
    ];
    if let Some(t) = tenant {
        fields.push(("tenant", json::s(t)));
    }
    json::obj(fields).to_json() + "\n"
}

/// Raw-socket frame reader with its OWN `\n` framing over a byte buffer.
/// `BufReader::read_line` under a read timeout can drop a partial line
/// on the timeout error path — exactly the corruption this suite exists
/// to catch — so the test client never uses it.
struct FrameReader {
    stream: TcpStream,
    buf: Vec<u8>,
    eof: bool,
}

impl FrameReader {
    fn new(stream: TcpStream) -> Self {
        stream
            .set_read_timeout(Some(Duration::from_millis(20)))
            .unwrap();
        FrameReader {
            stream,
            buf: Vec::new(),
            eof: false,
        }
    }

    /// Next complete frame, or `None` on EOF-with-empty-buffer or
    /// deadline expiry. Timeout reads retry; framing never tears.
    fn next_frame(&mut self, deadline: Instant) -> Option<Value> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.buf.drain(..=pos).collect();
                let text = String::from_utf8(line).expect("server frames are UTF-8");
                return Some(json::parse(text.trim()).expect("server frames are JSON"));
            }
            if self.eof || Instant::now() >= deadline {
                return None;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => self.eof = true,
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) => {}
                Err(_) => self.eof = true,
            }
        }
    }

    /// Read frames until `n` terminal frames (`done` / `error`, or
    /// event-less aggregate replies) have arrived.
    fn collect_until_terminals(&mut self, n: usize, deadline: Instant) -> Vec<Value> {
        let mut frames = Vec::new();
        let mut terminals = 0;
        while terminals < n {
            let Some(v) = self.next_frame(deadline) else {
                panic!(
                    "stream ended after {terminals}/{n} terminals ({} frames): {:?}",
                    frames.len(),
                    frames.iter().map(|f| f.to_json()).collect::<Vec<_>>()
                );
            };
            if is_terminal(&v) {
                terminals += 1;
            }
            frames.push(v);
        }
        frames
    }
}

/// Terminal = stream `done`/`error` frame or an aggregate reply line
/// (which has no `event` field at all).
fn is_terminal(v: &Value) -> bool {
    match v.get("event").and_then(|e| e.as_str()) {
        Some("token") => false,
        Some(_) => true,
        None => true,
    }
}

fn rid_of(v: &Value) -> Option<usize> {
    v.get("rid").and_then(|r| r.as_usize())
}

fn event_of(v: &Value) -> &str {
    v.get("event").and_then(|e| e.as_str()).unwrap_or("")
}

fn kind_of(v: &Value) -> &str {
    v.get("error_kind").and_then(|k| k.as_str()).unwrap_or("")
}

/// The streamed view of one rid: token frames in arrival order plus the
/// terminal frame, checked for the per-stream frame grammar (indices
/// strictly increasing, exactly one terminal, terminal last).
struct StreamView {
    tokens: Vec<(usize, u32, String)>,
    terminal: Value,
}

/// Fallible so the chaos property reports violations through the prop
/// harness (which prints the failing seed); plain tests `.unwrap()`.
fn demux(frames: &[Value], rid: usize) -> Result<StreamView, String> {
    let mut tokens: Vec<(usize, u32, String)> = Vec::new();
    let mut terminal: Option<Value> = None;
    for f in frames.iter().filter(|f| rid_of(f) == Some(rid)) {
        match event_of(f) {
            "token" => {
                if terminal.is_some() {
                    return Err(format!(
                        "rid {rid}: token frame after the terminal: {}",
                        f.to_json()
                    ));
                }
                let index = f
                    .get("index")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| format!("rid {rid}: token frame without index"))?;
                let id = f.get("id").and_then(|v| v.as_i64()).unwrap_or(0) as u32;
                let text = f
                    .get("text")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("rid {rid}: token frame without text"))?
                    .to_string();
                tokens.push((index, id, text));
            }
            "done" | "error" => {
                if terminal.is_some() {
                    return Err(format!(
                        "rid {rid}: second terminal frame: {}",
                        f.to_json()
                    ));
                }
                terminal = Some(f.clone());
            }
            other => {
                return Err(format!(
                    "rid {rid}: unknown event {other:?}: {}",
                    f.to_json()
                ))
            }
        }
    }
    let terminal = terminal.ok_or_else(|| format!("rid {rid}: no terminal frame"))?;
    for w in tokens.windows(2) {
        if w[1].0 <= w[0].0 {
            return Err(format!(
                "rid {rid}: token indices not strictly increasing: {} then {}",
                w[0].0, w[1].0
            ));
        }
    }
    // the streaming-identity law at the frame level: a successful
    // terminal aggregates exactly the streamed tokens
    if terminal.get("ok").and_then(|v| v.as_bool()) == Some(true) {
        let concat: String = tokens.iter().map(|(_, _, t)| t.as_str()).collect();
        let output = terminal
            .get("output")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("rid {rid}: done frame without output"))?;
        if concat != output {
            return Err(format!(
                "rid {rid}: concat(token.text) {concat:?} != done.output {output:?}"
            ));
        }
        if terminal.get("new_tokens").and_then(|v| v.as_usize()) != Some(tokens.len()) {
            return Err(format!(
                "rid {rid}: done.new_tokens != {} streamed tokens",
                tokens.len()
            ));
        }
    }
    Ok(StreamView { tokens, terminal })
}

fn front_i64(stats: &Value, key: &str) -> i64 {
    stats
        .get("front")
        .and_then(|f| f.get(key))
        .and_then(|v| v.as_i64())
        .unwrap_or_else(|| panic!("missing front.{key} in {}", stats.to_json()))
}

fn tenant_i64(stats: &Value, tenant: &str, key: &str) -> i64 {
    stats
        .get("front")
        .and_then(|f| f.get("tenants"))
        .and_then(|t| t.get(tenant))
        .and_then(|c| c.get(key))
        .and_then(|v| v.as_i64())
        .unwrap_or_else(|| panic!("missing front.tenants.{tenant}.{key} in {}", stats.to_json()))
}

fn arena_used(stats: &Value) -> i64 {
    stats
        .get("stats")
        .and_then(|s| s.get("aggregate"))
        .and_then(|a| a.get("arena_used_blocks"))
        .and_then(|v| v.as_i64())
        .expect("aggregate.arena_used_blocks in stats")
}

/// Poll the wire stats until the serving path is fully drained: no
/// front-queued or inflight requests and zero arena blocks in use (the
/// conservation law, observed over the wire).
fn try_wait_drained(addr: SocketAddr, deadline: Instant) -> Result<(), String> {
    let mut client = TcpClient::connect(addr).map_err(|e| e.to_string())?;
    loop {
        let s = client.stats().map_err(|e| e.to_string())?;
        let used = arena_used(&s);
        let queued = front_i64(&s, "queued");
        let inflight = front_i64(&s, "inflight");
        if used == 0 && queued == 0 && inflight == 0 {
            return Ok(());
        }
        if Instant::now() >= deadline {
            return Err(format!(
                "serving path did not drain: arena_used_blocks={used} queued={queued} inflight={inflight}"
            ));
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn wait_drained(addr: SocketAddr) {
    try_wait_drained(addr, Instant::now() + Duration::from_secs(10)).unwrap();
}

// --- framing + identity ----------------------------------------------------

#[test]
fn streamed_tokens_reassemble_the_aggregate_reply() {
    let (_c, server) = spawn_stack_with(drainable_cfg());
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let prompt = "stream me the capital of france";
    let streamed = client
        .generate_streaming(prompt, 6, None, None)
        .unwrap();
    assert!(streamed.is_ok(), "terminal: {}", streamed.done.to_json());
    assert_eq!(streamed.tokens.len(), 6);
    assert!(
        streamed.ttft.is_some(),
        "a successful stream must record client-visible TTFT"
    );
    // done carries the aggregate payload: it IS the whole reply
    let output = streamed
        .done
        .get("output")
        .and_then(|v| v.as_str())
        .unwrap()
        .to_string();
    assert_eq!(streamed.text(), output);
    assert_eq!(
        streamed.done.get("new_tokens").and_then(|v| v.as_usize()),
        Some(streamed.tokens.len())
    );
    // the same request in aggregate mode produces the identical output
    // (populate_cache off: both runs are cold, so byte-identical)
    let agg = client.request(prompt, 6, None).unwrap();
    assert_eq!(agg.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(agg.get("output").and_then(|v| v.as_str()), Some(output.as_str()));
    wait_drained(server.addr());
    server.stop();
}

#[test]
fn interleaved_streams_on_one_connection_demux_by_rid() {
    let (_c, server) = spawn_stack_with(ServerConfig {
        num_workers: num_workers_from_env(),
        ..Default::default()
    });
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    // three streams pipelined in ONE write: their frames may interleave
    // arbitrarily on the wire; the echoed rid is the only demux key
    let batch: String = [
        stream_line(0, "first interleaved stream", 3, None),
        stream_line(1, "second interleaved stream", 4, None),
        stream_line(2, "third interleaved stream", 5, None),
    ]
    .concat();
    w.write_all(batch.as_bytes()).unwrap();
    let mut r = FrameReader::new(stream);
    let frames = r.collect_until_terminals(3, Instant::now() + Duration::from_secs(30));
    for (rid, want) in [(0usize, 3usize), (1, 4), (2, 5)] {
        let view = demux(&frames, rid).unwrap();
        assert_eq!(event_of(&view.terminal), "done", "rid {rid} failed: {}", view.terminal.to_json());
        assert_eq!(view.tokens.len(), want, "rid {rid}: wrong token count");
    }
    server.stop();
}

#[test]
fn mid_stream_garbage_gets_typed_error_and_stream_survives() {
    // garbage lines arriving WHILE a stream is in flight must produce
    // typed error replies on the live connection without tearing the
    // stream — the paced model keeps the stream open across the garbage
    let (_c, server) = spawn_stack_opts(
        ServerConfig {
            num_workers: num_workers_from_env(),
            ..Default::default()
        },
        Some(Duration::from_millis(2)),
        FaultHandle::off(),
    );
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(stream_line(7, "a stream that must survive garbage", 6, None).as_bytes())
        .unwrap();
    w.write_all(b"this is not json\n").unwrap();
    w.write_all(b"\xff\xfe not utf8 \x80\n").unwrap();
    let mut r = FrameReader::new(stream);
    // 3 terminals: the stream's done + two aggregate error replies
    let frames = r.collect_until_terminals(3, Instant::now() + Duration::from_secs(30));
    let garbage: Vec<&Value> = frames.iter().filter(|f| event_of(f).is_empty()).collect();
    assert_eq!(garbage.len(), 2, "expected two aggregate error replies");
    for g in &garbage {
        assert_eq!(g.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(kind_of(g), "json", "wrong kind: {}", g.to_json());
    }
    assert!(
        garbage
            .iter()
            .any(|g| g.get("error").and_then(|v| v.as_str()).unwrap_or("").contains("UTF-8")),
        "the invalid-UTF-8 line must say so"
    );
    let view = demux(&frames, 7).unwrap();
    assert_eq!(event_of(&view.terminal), "done", "stream torn by garbage: {}", view.terminal.to_json());
    assert_eq!(view.tokens.len(), 6);
    // connection still serves after the garbage
    w.write_all(br#"{"prompt": "after the garbage", "max_new_tokens": 2}"#)
        .unwrap();
    w.write_all(b"\n").unwrap();
    let probe = r
        .next_frame(Instant::now() + Duration::from_secs(10))
        .expect("probe reply");
    assert_eq!(probe.get("ok").and_then(|v| v.as_bool()), Some(true));
    server.stop();
}

// --- half-close and disconnect resource release ----------------------------

#[test]
fn half_close_drains_stream_then_server_reaps() {
    let (_c, server) = spawn_stack_with(drainable_cfg());
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let batch: String = [
        stream_line(0, "half closed but fully served", 4, None),
        // pipelined aggregate request on the same dying connection
        r#"{"prompt": "aggregate before the close", "max_new_tokens": 2}"#.to_string() + "\n",
    ]
    .concat();
    w.write_all(batch.as_bytes()).unwrap();
    // half-close: server sees EOF but must drain both replies first
    stream.shutdown(Shutdown::Write).unwrap();
    let mut r = FrameReader::new(stream);
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut frames = Vec::new();
    while let Some(f) = r.next_frame(deadline) {
        frames.push(f);
    }
    assert!(r.eof, "server must close the drained half-closed connection");
    let view = demux(&frames, 0).unwrap();
    assert_eq!(event_of(&view.terminal), "done");
    assert_eq!(view.tokens.len(), 4);
    let agg: Vec<&Value> = frames.iter().filter(|f| event_of(f).is_empty()).collect();
    assert_eq!(agg.len(), 1, "exactly one aggregate reply");
    assert_eq!(agg[0].get("ok").and_then(|v| v.as_bool()), Some(true));
    // every slot and block released (fresh connection: the old one is gone)
    wait_drained(server.addr());
    server.stop();
}

#[test]
fn mid_stream_disconnect_releases_slots_and_blocks() {
    // a client vanishing mid-stream must not leak its slot or arena
    // blocks: the paced model guarantees the drop lands mid-generation
    let (_c, server) = spawn_stack_opts(
        ServerConfig {
            num_workers: 1,
            populate_cache: false,
            ..Default::default()
        },
        Some(Duration::from_millis(2)),
        FaultHandle::off(),
    );
    {
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(stream_line(0, "doomed client mid stream", 64, None).as_bytes())
            .unwrap();
        let mut r = FrameReader::new(stream);
        let first = r
            .next_frame(Instant::now() + Duration::from_secs(10))
            .expect("at least one token frame before the disconnect");
        assert_eq!(event_of(&first), "token");
        // dropped here: RST/FIN mid-stream, ~126 tokens still unwritten
    }
    try_wait_drained(server.addr(), Instant::now() + Duration::from_secs(15)).unwrap();
    // the front still serves new clients after the abandonment
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let r = client.request("alive after the disconnect", 2, None).unwrap();
    assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
    server.stop();
}

// --- per-tenant QoS --------------------------------------------------------

#[test]
fn stats_reports_per_tenant_front_counters() {
    let (_c, server) = spawn_stack_with(drainable_cfg());
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let a1 = client
        .generate_streaming("alice first question", 3, None, Some("alice"))
        .unwrap();
    assert!(a1.is_ok());
    let a2 = client
        .generate_streaming("alice second question", 5, None, Some("alice"))
        .unwrap();
    assert!(a2.is_ok());
    let b = client
        .request_opts("bob aggregate question", 2, None, Some("bob"))
        .unwrap();
    assert_eq!(b.get("ok").and_then(|v| v.as_bool()), Some(true));
    let s = client.stats().unwrap();
    assert_eq!(tenant_i64(&s, "alice", "accepted"), 2);
    assert_eq!(tenant_i64(&s, "alice", "completed"), 2);
    assert_eq!(tenant_i64(&s, "alice", "shed"), 0);
    assert_eq!(tenant_i64(&s, "alice", "tokens_streamed"), 3 + 5);
    assert_eq!(tenant_i64(&s, "alice", "first_tokens"), 2);
    assert_eq!(tenant_i64(&s, "alice", "weight"), 1);
    assert_eq!(tenant_i64(&s, "bob", "accepted"), 1);
    assert_eq!(tenant_i64(&s, "bob", "completed"), 1);
    // aggregate requests stream nothing
    assert_eq!(tenant_i64(&s, "bob", "tokens_streamed"), 0);
    assert_eq!(
        s.get("front")
            .and_then(|f| f.get("overloaded"))
            .and_then(|v| v.as_bool()),
        Some(false)
    );
    wait_drained(server.addr());
    server.stop();
}

#[test]
fn tenant_queue_overflow_sheds_typed_overloaded_not_silent_drops() {
    // downstream intentionally tiny (queue_capacity 1, max_batch 1, paced
    // model): the front's pump backs up immediately, so a burst overflows
    // the 2-deep tenant queue and sheds — every shed must be a typed
    // `overloaded` terminal on the live stream, never a dropped rid
    let (_c, server) = spawn_stack_opts(
        ServerConfig {
            num_workers: 1,
            queue_capacity: 1,
            max_batch: 1,
            tenant_queue_capacity: 2,
            populate_cache: false,
            ..Default::default()
        },
        Some(Duration::from_micros(500)),
        FaultHandle::off(),
    );
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let n = 8usize;
    let batch: String = (0..n)
        .map(|rid| stream_line(rid, &format!("burst request number {rid}"), 4, None))
        .collect();
    // one write: the whole burst lands in one read pass, before any pump
    w.write_all(batch.as_bytes()).unwrap();
    let mut r = FrameReader::new(stream);
    let frames = r.collect_until_terminals(n, Instant::now() + Duration::from_secs(30));
    let mut shed = 0;
    let mut done = 0;
    for rid in 0..n {
        let view = demux(&frames, rid).unwrap();
        match event_of(&view.terminal) {
            "done" => {
                done += 1;
                assert_eq!(view.tokens.len(), 4, "rid {rid}");
            }
            "error" => {
                assert_eq!(
                    kind_of(&view.terminal),
                    "overloaded",
                    "rid {rid}: wrong kind: {}",
                    view.terminal.to_json()
                );
                assert!(view.tokens.is_empty(), "rid {rid}: shed after tokens");
                shed += 1;
            }
            other => panic!("rid {rid}: unexpected terminal {other:?}"),
        }
    }
    assert!(shed >= 1, "an 8-burst into a 2-deep queue must shed");
    assert!(done >= 1, "queued requests must still complete");
    // the sheds are visible in the per-tenant counters
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let s = client.stats().unwrap();
    assert_eq!(tenant_i64(&s, "anon", "shed"), shed);
    assert_eq!(tenant_i64(&s, "anon", "completed"), done);
    wait_drained(server.addr());
    server.stop();
}

#[test]
fn front_queue_deadline_is_a_typed_error_not_a_hang() {
    // a slow backlog against a short request budget: late requests must
    // die with `deadline_exceeded` (front-queue or scheduler-side — both
    // carry the same kind), and early ones must still complete
    let (_c, server) = spawn_stack_opts(
        ServerConfig {
            num_workers: 1,
            queue_capacity: 1,
            max_batch: 1,
            request_timeout_ms: 150,
            populate_cache: false,
            ..Default::default()
        },
        Some(Duration::from_millis(5)),
        FaultHandle::off(),
    );
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let n = 12usize;
    let batch: String = (0..n)
        .map(|rid| stream_line(rid, &format!("deadline probe {rid}"), 4, None))
        .collect();
    w.write_all(batch.as_bytes()).unwrap();
    let mut r = FrameReader::new(stream);
    let frames = r.collect_until_terminals(n, Instant::now() + Duration::from_secs(30));
    let mut expired = 0;
    let mut done = 0;
    for rid in 0..n {
        let view = demux(&frames, rid).unwrap();
        match event_of(&view.terminal) {
            "done" => done += 1,
            "error" => {
                let kind = kind_of(&view.terminal).to_string();
                assert!(
                    kind == "deadline_exceeded" || kind == "overloaded",
                    "rid {rid}: unexpected kind {kind:?}"
                );
                if kind == "deadline_exceeded" {
                    expired += 1;
                }
            }
            other => panic!("rid {rid}: unexpected terminal {other:?}"),
        }
    }
    assert!(done >= 1, "the head of the backlog must complete in budget");
    assert!(
        expired >= 1,
        "a ~240ms backlog against a 150ms budget must expire some requests"
    );
    server.stop();
}

#[test]
fn wait_gate_sheds_new_arrivals_under_live_overload() {
    // qos_shed_wait_ms=1 arms the live overload gate: once the worker
    // queue wait (differenced from scheduler snapshots) crosses 1ms, NEW
    // arrivals shed typed instead of joining the latency tail
    let (_c, server) = spawn_stack_opts(
        ServerConfig {
            num_workers: 1,
            max_batch: 1,
            qos_shed_wait_ms: 1,
            populate_cache: false,
            ..Default::default()
        },
        Some(Duration::from_millis(2)),
        FaultHandle::off(),
    );
    // flood: 24 streams x 8 tokens x 2ms ≈ 380ms of serialized backlog
    let flood = TcpStream::connect(server.addr()).unwrap();
    let mut fw = flood.try_clone().unwrap();
    let batch: String = (0..24)
        .map(|rid| stream_line(rid, &format!("flood request {rid}"), 8, None))
        .collect();
    fw.write_all(batch.as_bytes()).unwrap();
    // probe until the gate trips and sheds one of ours
    let mut probe = TcpClient::connect(server.addr()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = probe.request("probe under overload", 1, None).unwrap();
        if r.get("ok").and_then(|v| v.as_bool()) == Some(false) {
            assert_eq!(kind_of(&r), "overloaded", "wrong shed kind: {}", r.to_json());
            break;
        }
        assert!(
            Instant::now() < deadline,
            "overload gate never tripped under a 380ms backlog"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(fw);
    drop(flood);
    server.stop();
}

// --- socket chaos ----------------------------------------------------------

#[test]
fn prop_socket_faults_never_tear_frames_or_leak() {
    // adversarial client I/O — stalled reads and torn writes at random
    // rates — must delay frames, never corrupt them: per rid exactly one
    // terminal, strictly increasing indices, identity on success, and
    // the serving path fully drained afterwards
    check("socket_faults_preserve_stream_contract", 5, |rng| {
        let plan = FaultPlan::new(rng.next_u64())
            .with_rate(FaultSite::ClientStall, rng.f64() * 0.2)
            .with_rate(FaultSite::TornClientWrite, rng.f64() * 0.4);
        let handle = plan.clone().install();
        let (_c, server) = spawn_stack_opts(
            ServerConfig {
                num_workers: 1,
                populate_cache: false,
                ..Default::default()
            },
            None,
            handle,
        );
        let n = rng.range(2, 7);
        let stream = TcpStream::connect(server.addr()).map_err(|e| e.to_string())?;
        let mut w = stream.try_clone().map_err(|e| e.to_string())?;
        let specs: Vec<(usize, usize)> = (0..n).map(|rid| (rid, rng.range(1, 9))).collect();
        let batch: String = specs
            .iter()
            .map(|&(rid, max_new)| {
                let prompt = format!("chaos {rid} {}", text(rng, 30));
                stream_line(rid, &prompt, max_new, None)
            })
            .collect();
        w.write_all(batch.as_bytes()).map_err(|e| e.to_string())?;
        let mut r = FrameReader::new(stream);
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut frames = Vec::new();
        let mut terminals = 0;
        while terminals < n {
            let Some(f) = r.next_frame(deadline) else {
                return Err(format!(
                    "stream ended after {terminals}/{n} terminals under {:?}",
                    plan
                ));
            };
            if is_terminal(&f) {
                terminals += 1;
            }
            frames.push(f);
        }
        for &(rid, max_new) in &specs {
            let view = demux(&frames, rid)?;
            prop_assert!(
                event_of(&view.terminal) == "done",
                "rid {rid}: socket faults must not fail requests: {}",
                view.terminal.to_json()
            );
            prop_assert!(
                view.tokens.len() == max_new,
                "rid {rid}: {} tokens streamed, wanted {max_new}",
                view.tokens.len()
            );
        }
        // the drain probe runs under the same fault rates — stalls and
        // torn writes only delay it, and the deadline absorbs that
        try_wait_drained(server.addr(), Instant::now() + Duration::from_secs(15))?;
        server.stop();
        Ok(())
    });
}
