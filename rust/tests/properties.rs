//! Property-based tests over the coordinator-side substrates, using the
//! in-house `testutil::prop` harness (proptest is not in the offline
//! vendor set). Each property runs over hundreds of seeded random inputs;
//! failures report the reproducing seed.

use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

use recycle_serve::bench::{multi_tenant_trace, TraceSpec};
use recycle_serve::config::{CacheConfig, EvictionPolicy, ModelConfig, RoutingPolicy, ServerConfig};
use recycle_serve::coordinator::{
    admission_prompt, Coordinator, Response, SchedEvent, SessionManager, StreamEvent,
};
use recycle_serve::engine::{plan_chunks, DecodeStream, Engine};
use recycle_serve::error::Error;
use recycle_serve::faults::{FaultHandle, FaultPlan, FaultSite};
use recycle_serve::testutil::trace::{run_script, shrink_script, Arrival, Script, TraceRun};
use recycle_serve::index::{FlatIndex, NgramEmbedder};
use recycle_serve::kvcache::{persist, BlockPool, Eviction, KvArena, KvRecord, KvStore, KvView};
use recycle_serve::prefix::{common_prefix_len, reuse_depth, RadixTree};
use recycle_serve::prop_assert;
use recycle_serve::recycler::{Admission, RecyclePolicy, Recycler};
use recycle_serve::testutil::prop::{check, text, tokens};
use recycle_serve::testutil::{MockModel, TempDir};
use recycle_serve::tokenizer::{pretokenize, Tokenizer};
use recycle_serve::util::json;
use recycle_serve::util::rng::Rng;

// ---------- tokenizer ----------

#[test]
fn prop_pretokenize_concat_identity() {
    check("pretokenize concat", 400, |rng| {
        let s = text(rng, 120);
        prop_assert!(pretokenize(&s).concat() == s, "pieces lost text: {s:?}");
        Ok(())
    });
}

#[test]
fn prop_bpe_roundtrip_merge_free() {
    let tok = Tokenizer::new(vec![]);
    check("bpe roundtrip (no merges)", 400, |rng| {
        let s = text(rng, 100);
        let dec = tok.decode(&tok.encode(&s));
        prop_assert!(dec == s, "{s:?} -> {dec:?}");
        Ok(())
    });
}

#[test]
fn prop_bpe_roundtrip_with_merges() {
    // synthesize a random-but-valid merge list over common letters
    let mut rng = Rng::new(99);
    let letters = ["a", "e", "i", "o", "t", "h", "n", "s"];
    let mut merges = Vec::new();
    for _ in 0..20 {
        let a = rng.choice(&letters).to_string();
        let b = rng.choice(&letters).to_string();
        if !merges.contains(&(a.clone(), b.clone())) {
            merges.push((a, b));
        }
    }
    let tok = Tokenizer::new(merges);
    check("bpe roundtrip (merges)", 300, |rng| {
        let s = text(rng, 100);
        let dec = tok.decode(&tok.encode(&s));
        prop_assert!(dec == s, "{s:?} -> {dec:?}");
        Ok(())
    });
}

#[test]
fn prop_bpe_prefix_stability_at_piece_boundary() {
    let tok = Tokenizer::new(vec![]);
    check("prefix stability", 300, |rng| {
        let a = text(rng, 60);
        let b = text(rng, 40);
        // appending a new space-separated word keeps the old ids a prefix
        let joined = format!("{a} x{b}");
        let ia = tok.encode(&a);
        let ij = tok.encode(&joined);
        if a.ends_with(|c: char| c.is_whitespace()) {
            return Ok(()); // boundary merges into the trailing space piece
        }
        prop_assert!(ij.len() >= ia.len() && ij[..ia.len()] == ia[..],
                     "prefix broke: {a:?} + x{b:?}");
        Ok(())
    });
}

// ---------- prefix / radix ----------

#[test]
fn prop_common_prefix_len_spec() {
    check("common_prefix_len", 500, |rng| {
        let a = tokens(rng, 0, 30, 64);
        let b = tokens(rng, 0, 30, 64);
        let r = common_prefix_len(&a, &b);
        prop_assert!(r <= a.len() && r <= b.len(), "r out of range");
        prop_assert!(a[..r] == b[..r], "not a common prefix");
        if r < a.len() && r < b.len() {
            prop_assert!(a[r] != b[r], "not maximal");
        }
        Ok(())
    });
}

#[test]
fn prop_reuse_depth_strictness() {
    check("reuse_depth strict", 500, |rng| {
        let c = tokens(rng, 0, 20, 32);
        let t = tokens(rng, 0, 20, 32);
        let (r, full) = reuse_depth(&c, &t);
        prop_assert!(full == (!c.is_empty() && r == c.len()),
                     "strict flag wrong: r={r} |c|={}", c.len());
        Ok(())
    });
}

#[test]
fn prop_radix_matches_linear_scan() {
    // the radix tree's longest_prefix must agree with a brute-force scan
    check("radix vs linear scan", 200, |rng| {
        let mut tree = RadixTree::new();
        let mut entries: Vec<(Vec<u32>, u64)> = Vec::new();
        let n = rng.range(1, 12);
        for key in 0..n as u64 {
            let seq = tokens(rng, 1, 10, 6); // tiny alphabet -> shared prefixes
            // replace semantics: keep latest key for duplicate seqs
            entries.retain(|(s, _)| *s != seq);
            entries.push((seq.clone(), key));
            tree.insert(&seq, key);
        }
        prop_assert!(tree.len() == entries.len(), "len mismatch");
        for _ in 0..10 {
            let q = tokens(rng, 0, 14, 6);
            let brute = entries
                .iter()
                .filter(|(s, _)| q.len() >= s.len() && q[..s.len()] == s[..])
                .max_by_key(|(s, key)| (s.len(), *key))
                .map(|(s, key)| (s.len(), *key));
            let got = tree.longest_prefix(&q);
            match (brute, got) {
                (None, None) => {}
                (Some((bd, _)), Some((gd, gk))) => {
                    prop_assert!(bd == gd, "depth {gd} != brute {bd} for {q:?}");
                    // key must be *a* valid entry at that depth
                    prop_assert!(
                        entries.iter().any(|(s, k)| s.len() == gd && *k == gk
                            && q[..gd] == s[..]),
                        "key {gk} not valid at depth {gd}"
                    );
                }
                other => prop_assert!(false, "mismatch {other:?} for {q:?}"),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_radix_insert_get_remove() {
    check("radix insert/get/remove", 200, |rng| {
        let mut tree = RadixTree::new();
        let mut reference: Vec<(Vec<u32>, u64)> = Vec::new();
        for step in 0..30 {
            let seq = tokens(rng, 0, 8, 4);
            if rng.chance(0.7) {
                let old = tree.insert(&seq, step);
                let ref_old = reference.iter().position(|(s, _)| *s == seq);
                prop_assert!(
                    old == ref_old.map(|i| reference[i].1),
                    "insert returned {old:?}"
                );
                if let Some(i) = ref_old {
                    reference[i].1 = step;
                } else {
                    reference.push((seq, step));
                }
            } else {
                let got = tree.remove(&seq);
                let ref_i = reference.iter().position(|(s, _)| *s == seq);
                prop_assert!(got == ref_i.map(|i| reference[i].1), "remove {got:?}");
                if let Some(i) = ref_i {
                    reference.remove(i);
                }
            }
            prop_assert!(tree.len() == reference.len(), "len diverged");
        }
        for (s, k) in &reference {
            prop_assert!(tree.get(s) == Some(*k), "get {s:?}");
        }
        Ok(())
    });
}

// ---------- kv store ----------

/// A record whose paged payload lives in `arena` (0.5-filled, `len` tokens).
fn rec_of(arena: &KvArena, len: usize, tag: usize) -> KvRecord {
    let g = arena.geometry();
    let data = vec![0.5f32; g.elems_per_token() * len];
    KvRecord {
        text: format!("p{tag}"),
        tokens: (0..len as u32).collect(),
        embedding: vec![1.0],
        kv: KvView::from_contiguous(arena, &data, len).unwrap(),
    }
}

#[test]
fn prop_store_capacity_and_accounting_invariants() {
    let cfg = ModelConfig::nano();
    check("store invariants", 150, |rng| {
        let arena = KvArena::new(&cfg, 16, 512);
        let max_entries = rng.range(1, 6);
        let policy = *rng.choice(&EvictionPolicy::ALL);
        let mut store = KvStore::new(CacheConfig {
            max_entries,
            max_bytes: 0,
            eviction: policy,
            ..Default::default()
        });
        let mut live: Vec<u64> = Vec::new();
        for step in 0..40 {
            match rng.below(3) {
                0 => {
                    let (id, evicted) = store.insert(rec_of(&arena, rng.range(1, 30), step));
                    for ev in &evicted {
                        let eid = ev.id();
                        live.retain(|x| *x != eid);
                    }
                    live.push(id);
                }
                1 => {
                    if !live.is_empty() {
                        let id = *rng.choice(&live);
                        prop_assert!(store.hit(id).is_some(), "live entry must hit");
                    }
                }
                _ => {
                    if !live.is_empty() && rng.chance(0.5) {
                        let id = live.remove(rng.below(live.len()));
                        prop_assert!(store.remove(id), "remove live");
                    }
                }
            }
            // invariants
            prop_assert!(store.len() <= max_entries, "capacity exceeded");
            prop_assert!(store.len() == live.len(), "live set diverged");
            let expect: usize = store.iter().map(|(_, r)| r.kv_bytes()).sum();
            prop_assert!(store.live_bytes() == expect, "byte accounting");
            // physical accounting: distinct hot blocks, counted once
            let mut distinct: Vec<usize> =
                store.iter().flat_map(|(_, r)| r.kv.block_ids()).collect();
            distinct.sort();
            distinct.dedup();
            prop_assert!(
                store.physical_blocks() == distinct.len(),
                "physical blocks {} != distinct {}",
                store.physical_blocks(),
                distinct.len()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_persist_roundtrip_random_records() {
    let cfg = ModelConfig::nano();
    check("persist roundtrip", 60, |rng| {
        let arena = KvArena::new(&cfg, 16, 64);
        let len = rng.range(0, 40);
        let mut rec = rec_of(&arena, len, 1);
        rec.text = text(rng, 50);
        rec.embedding = (0..rng.range(1, 20)).map(|_| rng.f64() as f32).collect();
        let compress = rng.chance(0.5);
        let buf = persist::to_bytes(&rec, compress);
        let back = persist::from_bytes(&buf, &arena).map_err(|e| e.to_string())?;
        prop_assert!(back.text == rec.text, "text");
        prop_assert!(back.tokens == rec.tokens, "tokens");
        prop_assert!(back.embedding == rec.embedding, "embedding");
        prop_assert!(
            back.kv.to_contiguous() == rec.kv.to_contiguous(),
            "payload"
        );
        Ok(())
    });
}

#[test]
fn prop_persist_rejects_random_corruption() {
    let cfg = ModelConfig::nano();
    check("persist corruption", 80, |rng| {
        let arena = KvArena::new(&cfg, 16, 64);
        let rec = rec_of(&arena, rng.range(1, 10), 2);
        let mut buf = persist::to_bytes(&rec, rng.chance(0.5));
        let i = rng.below(buf.len());
        let bit = 1u8 << rng.below(8);
        buf[i] ^= bit;
        // either detected as corrupt, or (crc collision: impossible for a
        // single bit flip) — must never return wrong data silently
        match persist::from_bytes(&buf, &arena) {
            Err(_) => Ok(()),
            Ok(back) => {
                prop_assert!(false, "bitflip at {i} accepted; len {}", back.kv.len());
                Ok(())
            }
        }
    });
}

#[test]
fn prop_persist_both_versions_roundtrip_and_reject_corruption() {
    let cfg = ModelConfig::nano();
    check("persist two codecs", 60, |rng| {
        let arena = KvArena::new(&cfg, 16, 64);
        let mut rec = rec_of(&arena, rng.range(0, 30), 3);
        rec.text = text(rng, 40);
        rec.embedding = (0..rng.range(1, 12)).map(|_| rng.f64() as f32).collect();
        let parts = persist::RecordParts::of(&rec);
        let geom = rec.kv.geometry();
        // the v1-raw encoding is bit-identical to the legacy serializer,
        // and its length is what the tier's logical meter charges
        let v1 = persist::encode(&parts, geom, persist::Codec::V1Raw);
        prop_assert!(
            v1 == persist::to_bytes(&rec, false),
            "v1 encoding drifted from the legacy serializer"
        );
        prop_assert!(
            parts.raw_encoded_len() == v1.len(),
            "logical length {} != raw encoding {}",
            parts.raw_encoded_len(),
            v1.len()
        );
        // every codec round-trips to the same record
        for codec in [
            persist::Codec::V1Raw,
            persist::Codec::V1PayloadDeflate,
            persist::Codec::V2Deflate,
        ] {
            let buf = persist::encode(&parts, geom, codec);
            let back =
                persist::from_bytes(&buf, &arena).map_err(|e| format!("{codec:?}: {e}"))?;
            prop_assert!(back.text == rec.text, "{codec:?}: text");
            prop_assert!(back.tokens == rec.tokens, "{codec:?}: tokens");
            prop_assert!(back.embedding == rec.embedding, "{codec:?}: embedding");
            prop_assert!(
                back.kv.to_contiguous() == rec.kv.to_contiguous(),
                "{codec:?}: payload"
            );
        }
        // v2: any truncation or single bitflip must surface as the typed
        // Corrupt error — the clean-miss contract, never wrong data
        let v2 = persist::encode(&parts, geom, persist::Codec::V2Deflate);
        let cut = rng.below(v2.len());
        match persist::from_bytes(&v2[..cut], &arena) {
            Err(Error::Corrupt(_)) => {}
            Err(e) => {
                prop_assert!(false, "truncation at {cut} wrong error kind: {e}");
            }
            Ok(_) => {
                prop_assert!(false, "truncation at {cut} accepted");
            }
        }
        let mut flipped = v2.clone();
        let i = rng.below(flipped.len());
        flipped[i] ^= 1u8 << rng.below(8);
        match persist::from_bytes(&flipped, &arena) {
            Err(Error::Corrupt(_)) => {}
            Err(e) => {
                prop_assert!(false, "bitflip at {i} wrong error kind: {e}");
            }
            Ok(_) => {
                prop_assert!(false, "bitflip at {i} accepted");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_store_exact_for_small_integer_payloads() {
    // Integer-valued payloads with |v| <= 127 are exactly representable
    // by the 8-bit block format (power-of-two scale 1.0), so a quantized
    // store must hand back bit-identical KV — while its residents pin
    // zero arena blocks and every materialized handle returns its blocks
    // on drop.
    let cfg = ModelConfig::nano();
    check("quantized store exactness", 40, |rng| {
        let arena = KvArena::new(&cfg, 16, 256);
        let mut store = KvStore::new(CacheConfig {
            max_entries: 0,
            max_bytes: 0,
            quantized_blocks: true,
            ..Default::default()
        });
        let baseline_free = arena.free_blocks();
        let g = arena.geometry().clone();
        let mut originals = Vec::new();
        for tag in 0..rng.range(1, 6) {
            let len = rng.range(1, 30);
            let mut data = vec![0f32; g.elems_per_token() * len];
            for v in data.iter_mut() {
                if rng.chance(0.2) {
                    *v = (rng.below(255) as i64 - 127) as f32;
                }
            }
            let rec = KvRecord {
                text: format!("q{tag}"),
                tokens: (0..len as u32).collect(),
                embedding: vec![1.0],
                kv: KvView::from_contiguous(&arena, &data, len).unwrap(),
            };
            let (id, _) = store.insert(rec);
            originals.push((id, data, len));
        }
        prop_assert!(
            store.physical_blocks() == 0,
            "quantized residents pinned {} arena blocks",
            store.physical_blocks()
        );
        prop_assert!(
            arena.free_blocks() == baseline_free,
            "arena not conserved after inserts: {} != {baseline_free}",
            arena.free_blocks()
        );
        for (id, data, len) in &originals {
            let rec = store
                .hit(*id)
                .ok_or_else(|| format!("quantized entry {id} must hit"))?;
            prop_assert!(rec.kv.len() == *len, "materialized length");
            prop_assert!(
                rec.kv.to_contiguous() == *data,
                "dequantize-on-attach must be exact for small integers"
            );
        }
        // every materialized handle has been dropped again
        prop_assert!(
            arena.free_blocks() == baseline_free,
            "materialized handles leaked arena blocks: {} != {baseline_free}",
            arena.free_blocks()
        );
        Ok(())
    });
}

// ---------- block pool ----------

#[test]
fn prop_block_pool_conservation() {
    check("block pool conservation", 150, |rng| {
        let cap = rng.range(1, 16);
        let pool = BlockPool::new(cap, 16);
        let mut held = Vec::new();
        for _ in 0..50 {
            if rng.chance(0.5) {
                if let Some(b) = pool.alloc() {
                    if rng.chance(0.3) {
                        held.push(b.clone()); // shared ref
                    }
                    held.push(b);
                }
            } else if !held.is_empty() {
                held.remove(rng.below(held.len()));
            }
            // conservation: free + distinct held blocks == capacity
            let mut ids: Vec<usize> = held.iter().map(|b| b.block_id).collect();
            ids.sort();
            ids.dedup();
            prop_assert!(
                pool.free_blocks() + ids.len() == cap,
                "free {} + held {} != cap {cap}",
                pool.free_blocks(),
                ids.len()
            );
        }
        Ok(())
    });
}

// ---------- kv arena ----------

/// Assert the arena's conservation invariants from a snapshot:
/// free + referenced == capacity; no block both free and referenced;
/// no block on the free list twice.
fn assert_arena_conserved(arena: &KvArena, ctx: &str) -> std::result::Result<(), String> {
    let (free, refs) = arena.snapshot();
    let held = refs.iter().filter(|&&c| c > 0).count();
    prop_assert!(
        free.len() + held == arena.capacity_blocks(),
        "{ctx}: free {} + held {held} != capacity {}",
        free.len(),
        arena.capacity_blocks()
    );
    let mut seen = vec![false; arena.capacity_blocks()];
    for &id in &free {
        prop_assert!(refs[id] == 0, "{ctx}: block {id} free with refcount {}", refs[id]);
        prop_assert!(!seen[id], "{ctx}: block {id} on the free list twice");
        seen[id] = true;
    }
    Ok(())
}

#[test]
fn prop_arena_accounting_under_hit_miss_evict_continue() {
    // Drive a KvStore + arena through random interleavings of the four
    // serving events — miss (admit a fresh view), hit (attach a record and
    // extend it COW, as generation does), evict (store removal / capacity
    // eviction), session-continue (attach, extend, admit the extension) —
    // with in-flight views outliving records and vice versa. The block
    // accounting must stay conserved at every step.
    let cfg = ModelConfig::nano();
    check("arena hit/miss/evict/continue", 80, |rng| {
        let arena = KvArena::new(&cfg, 8, 512);
        let mut store = KvStore::new(CacheConfig {
            max_entries: rng.range(1, 5),
            max_bytes: 0,
            eviction: *rng.choice(&EvictionPolicy::ALL),
            ..Default::default()
        });
        let mut inflight: Vec<KvView> = Vec::new();
        for step in 0..60 {
            match rng.below(5) {
                // miss: prefill-like fresh view, admitted to the cache
                // (skipped under arena pressure, like a real admit would be)
                0 => {
                    let len = rng.range(1, 30);
                    let g = arena.geometry();
                    let data = vec![0.5f32; g.elems_per_token() * len];
                    if let Ok(view) = KvView::from_contiguous(&arena, &data, len) {
                        let tokens: Vec<u32> = (0..len as u32).collect();
                        let rec = KvRecord::from_view(
                            &format!("p{step}"), tokens, vec![1.0], &view,
                        );
                        let (_, _evicted) = store.insert(rec);
                    }
                }
                // hit: attach a cached record, extend it like decode does
                1 => {
                    let ids = store.ids();
                    if !ids.is_empty() {
                        let id = *rng.choice(&ids);
                        let rec = store.hit(id).expect("live entry");
                        let mut v = rec.attach();
                        let extra = rng.range(1, 10);
                        for pos in v.len()..v.len() + extra {
                            if v.row_mut(0, 0, 0, pos).is_err() {
                                break; // arena pressure: stop extending
                            }
                            v.commit(pos + 1);
                        }
                        if rng.chance(0.6) {
                            inflight.push(v);
                        }
                    }
                }
                // session-continue: attach + extend + admit the extension
                2 => {
                    let ids = store.ids();
                    if !ids.is_empty() {
                        let id = *rng.choice(&ids);
                        let rec = store.hit(id).expect("live entry");
                        let mut v = rec.attach();
                        let extra = rng.range(1, 8);
                        let target = v.len() + extra;
                        let mut ok = true;
                        for pos in v.len()..target {
                            if v.row_mut(0, 0, 0, pos).is_err() {
                                ok = false;
                                break;
                            }
                            v.commit(pos + 1);
                        }
                        if ok {
                            let tokens: Vec<u32> = (0..target as u32).collect();
                            store.insert(KvRecord::from_view(
                                "cont", tokens, vec![1.0], &v,
                            ));
                        }
                    }
                }
                // explicit evict
                3 => {
                    let ids = store.ids();
                    if !ids.is_empty() {
                        store.remove(*rng.choice(&ids));
                    }
                }
                // request completion: drop an in-flight view
                _ => {
                    if !inflight.is_empty() {
                        let i = rng.below(inflight.len());
                        inflight.remove(i);
                    }
                }
            }
            assert_arena_conserved(&arena, &format!("step {step}"))?;
        }
        // drain everything: all blocks must return to the pool
        drop(store);
        inflight.clear();
        prop_assert!(
            arena.free_blocks() == arena.capacity_blocks(),
            "leak: {} of {} blocks free after drain",
            arena.free_blocks(),
            arena.capacity_blocks()
        );
        Ok(())
    });
}

/// The set of `<id>.kv` files in a spill dir with their sizes.
fn spill_files(dir: &std::path::Path) -> Vec<(u64, usize)> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.extension().is_some_and(|x| x == "kv") {
                if let Some(id) = p
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    let bytes = e.metadata().map(|m| m.len() as usize).unwrap_or(0);
                    out.push((id, bytes));
                }
            }
        }
    }
    out.sort();
    out
}

#[test]
fn prop_tiered_store_three_state_conservation_and_eviction_yield() {
    // THE tiered-store conservation property, over random interleavings of
    // miss-admit / hit-extend / session-continue / evict (spill) / reload /
    // remove / request-completion events:
    //
    //  * arena blocks: free + hot-referenced == capacity at every step —
    //    a spilled record holds ZERO arena blocks; its payload is
    //    conserved on disk instead, as the tier's cold_bytes (the
    //    three-state "free + hot + spilled" invariant, with the cold
    //    state measured in serialized bytes);
    //  * the on-disk file set is exactly the spilled id set and its sizes
    //    sum to cold_bytes;
    //  * store physical accounting == distinct hot block ids;
    //  * every eviction's reported freed_blocks equals the arena's actual
    //    free-count delta once the eviction settles (the acceptance
    //    invariant for shared-aware physical accounting).
    let cfg = ModelConfig::nano();
    check("tiered store conservation", 40, |rng| {
        let tmp = TempDir::new("tier_prop");
        let arena = KvArena::new(&cfg, 8, 256);
        let small_tier = rng.chance(0.3); // sometimes force tier-LRU drops
        let mut store = KvStore::new(CacheConfig {
            max_entries: rng.range(1, 6),
            max_bytes: 0,
            eviction: *rng.choice(&EvictionPolicy::ALL),
            compress: rng.chance(0.5),
            // physical cold_bytes == summed file sizes must hold under
            // BOTH on-disk codecs (v2 just makes the files smaller)
            spill_compression: rng.chance(0.5),
            max_spill_bytes: if small_tier { 200_000 } else { 64 << 20 },
            spill_dir: Some(tmp.path_string()),
            ..Default::default()
        });
        let mut cold: std::collections::HashSet<u64> = std::collections::HashSet::new();
        let mut inflight: Vec<KvView> = Vec::new();
        fn apply(ev: &Eviction, cold: &mut std::collections::HashSet<u64>) {
            if ev.is_spilled() {
                cold.insert(ev.id());
            }
        }
        for step in 0..50 {
            match rng.below(6) {
                // miss: admit a fresh record
                0 => {
                    let len = rng.range(1, 30);
                    let g = arena.geometry();
                    let data = vec![0.5f32; g.elems_per_token() * len];
                    if let Ok(view) = KvView::from_contiguous(&arena, &data, len) {
                        let tokens: Vec<u32> = (0..len as u32).collect();
                        let rec = KvRecord::from_view(
                            &format!("p{step}"),
                            tokens,
                            vec![1.0],
                            &view,
                        );
                        let (_, evicted) = store.insert(rec);
                        for ev in &evicted {
                            apply(ev, &mut cold);
                        }
                    }
                }
                // hit: attach a hot record, extend it like decode does
                1 => {
                    let ids = store.ids();
                    if !ids.is_empty() {
                        let id = *rng.choice(&ids);
                        let rec = store.hit(id).expect("hot entry");
                        let mut v = rec.attach();
                        drop(rec);
                        let extra = rng.range(1, 10);
                        for pos in v.len()..v.len() + extra {
                            if v.row_mut(0, 0, 0, pos).is_err() {
                                break; // arena pressure: stop extending
                            }
                            v.commit(pos + 1);
                        }
                        if rng.chance(0.6) {
                            inflight.push(v);
                        }
                    }
                }
                // session-continue: attach + extend + admit the extension
                2 => {
                    let ids = store.ids();
                    if !ids.is_empty() {
                        let id = *rng.choice(&ids);
                        let rec = store.hit(id).expect("hot entry");
                        let mut v = rec.attach();
                        drop(rec);
                        let target = v.len() + rng.range(1, 8);
                        let mut ok = true;
                        for pos in v.len()..target {
                            if v.row_mut(0, 0, 0, pos).is_err() {
                                ok = false;
                                break;
                            }
                            v.commit(pos + 1);
                        }
                        if ok {
                            let tokens: Vec<u32> = (0..target as u32).collect();
                            let (_, evicted) = store
                                .insert(KvRecord::from_view("cont", tokens, vec![1.0], &v));
                            for ev in &evicted {
                                apply(ev, &mut cold);
                            }
                        }
                    }
                }
                // pressure eviction, with the yield invariant checked
                3 => {
                    let free_before = arena.free_blocks();
                    if let Some(ev) = store.evict_one() {
                        let freed = ev.freed_blocks();
                        apply(&ev, &mut cold);
                        drop(ev); // settles a Dropped victim's blocks
                        prop_assert!(
                            arena.free_blocks() == free_before + freed,
                            "step {step}: eviction reported {freed} freed blocks, \
                             arena went {free_before} -> {}",
                            arena.free_blocks()
                        );
                    }
                }
                // transparent reload of a spilled record
                4 => {
                    let cold_ids: Vec<u64> = cold.iter().copied().collect();
                    if !cold_ids.is_empty() {
                        let id = *rng.choice(&cold_ids);
                        let (rec, evicted) = store.reload_spilled(id, &arena);
                        for ev in &evicted {
                            apply(ev, &mut cold);
                        }
                        if rec.is_some() {
                            cold.remove(&id);
                        }
                        // on failure the entry is either still cold
                        // (retryable arena pressure) or was collaterally
                        // LRU-dropped by a shed-spill — the
                        // take_cold_dropped drain below reconciles the
                        // mirror either way, and the global spilled-set /
                        // file-set invariants catch any desync
                    }
                }
                // request completion: drop an in-flight view
                _ => {
                    if !inflight.is_empty() {
                        let i = rng.below(inflight.len());
                        inflight.remove(i);
                    }
                }
            }
            for d in store.take_cold_dropped() {
                cold.remove(&d);
            }
            // arena conservation: spilled records hold no blocks
            assert_arena_conserved(&arena, &format!("step {step}"))?;
            // store physical accounting == distinct hot block ids
            let mut distinct: Vec<usize> =
                store.iter().flat_map(|(_, r)| r.kv.block_ids()).collect();
            distinct.sort();
            distinct.dedup();
            prop_assert!(
                store.physical_blocks() == distinct.len(),
                "step {step}: physical {} != distinct {}",
                store.physical_blocks(),
                distinct.len()
            );
            // cold-tier conservation: tracked set == on-disk set, sizes
            // sum to cold_bytes
            prop_assert!(
                store.spilled_len() == cold.len(),
                "step {step}: spilled_len {} != tracked {}",
                store.spilled_len(),
                cold.len()
            );
            let files = spill_files(tmp.path());
            let mut want: Vec<u64> = cold.iter().copied().collect();
            want.sort();
            let got: Vec<u64> = files.iter().map(|(id, _)| *id).collect();
            prop_assert!(got == want, "step {step}: on-disk {got:?} != {want:?}");
            let disk_bytes: usize = files.iter().map(|(_, b)| *b).sum();
            prop_assert!(
                disk_bytes == store.cold_bytes(),
                "step {step}: disk {disk_bytes} != cold_bytes {}",
                store.cold_bytes()
            );
        }
        // drain everything: every arena block must return to the pool
        drop(store);
        inflight.clear();
        prop_assert!(
            arena.free_blocks() == arena.capacity_blocks(),
            "leak: {} of {} blocks free after drain",
            arena.free_blocks(),
            arena.capacity_blocks()
        );
        Ok(())
    });
}

#[test]
fn prop_view_cow_isolation() {
    // Random writes through a cloned view never alter the donor, and the
    // arena stays conserved through every COW block copy.
    let cfg = ModelConfig::nano();
    check("view COW isolation", 100, |rng| {
        let arena = KvArena::new(&cfg, 8, 64);
        let len = rng.range(1, 40);
        let donor = {
            let g = arena.geometry();
            let data: Vec<f32> =
                (0..g.elems_per_token() * len).map(|i| i as f32 * 0.25).collect();
            KvView::from_contiguous(&arena, &data, len).unwrap()
        };
        let before = donor.to_contiguous();
        let mut copy = donor.clone();
        for _ in 0..rng.range(1, 12) {
            let pos = rng.below(len);
            let layer = rng.below(cfg.n_layer);
            let head = rng.below(cfg.n_head);
            let kv = rng.below(2);
            copy.row_mut(layer, kv, head, pos)
                .map_err(|e| e.to_string())?[0] = -1.0;
        }
        prop_assert!(donor.to_contiguous() == before, "donor mutated through clone");
        assert_arena_conserved(&arena, "after COW writes")?;
        Ok(())
    });
}

#[test]
fn prop_view_truncate_preserves_prefix_and_frees_blocks() {
    let cfg = ModelConfig::nano();
    check("view truncate", 100, |rng| {
        let arena = KvArena::new(&cfg, 8, 64);
        let len = rng.range(1, 40);
        let g = arena.geometry().clone();
        let data: Vec<f32> =
            (0..g.elems_per_token() * len).map(|i| (i % 53) as f32).collect();
        let mut v = KvView::from_contiguous(&arena, &data, len).unwrap();
        let cut = rng.below(len + 1);
        v.truncate(cut);
        prop_assert!(v.len() == cut, "len after truncate");
        prop_assert!(
            v.num_blocks() == cut.div_ceil(g.block_tokens),
            "blocks after truncate"
        );
        // the surviving prefix reads back unchanged
        let kept = v.to_contiguous();
        for plane in 0..g.planes() {
            for pos in 0..cut {
                for x in 0..g.head_dim {
                    let got = kept[(plane * cut + pos) * g.head_dim + x];
                    let want = data[(plane * len + pos) * g.head_dim + x];
                    prop_assert!(got == want, "plane {plane} pos {pos} elem {x}");
                }
            }
        }
        assert_arena_conserved(&arena, "after truncate")?;
        Ok(())
    });
}

// ---------- continuous batching ----------

/// One request in the randomized serving workload: an optional session
/// (turn prompts extend the committed transcript) and a prompt text.
struct ReqSpec {
    session: Option<usize>,
    msg: String,
    max_new: usize,
}

fn mk_recycler(policy: RecyclePolicy) -> Recycler<MockModel> {
    Recycler::new(
        Engine::new(MockModel::new(ModelConfig::nano())),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        CacheConfig {
            max_entries: 8,
            ..Default::default()
        },
        policy,
    )
}

/// Build the prompt (text, ids, admit_full) for a request, mirroring the
/// coordinator's admission (token-level session continuation).
fn build_prompt(
    r: &Recycler<MockModel>,
    sessions: &SessionManager,
    q: &ReqSpec,
) -> (String, Vec<u32>, bool) {
    match q.session {
        Some(sid) => {
            let key = format!("s{sid}");
            let seg = sessions.segment_for(&key, &q.msg);
            let (mut text, mut ids) = sessions.state_of(&key);
            text.push_str(&seg);
            ids.extend(r.tokenizer().encode(&seg));
            (text, ids, true)
        }
        None => (q.msg.clone(), r.tokenizer().encode(&q.msg), false),
    }
}

fn commit_turn(
    sessions: &mut SessionManager,
    q: &ReqSpec,
    text: &str,
    ids: &[u32],
    out_ids: &[u32],
    out_text: &str,
) {
    if let Some(sid) = q.session {
        let mut full_ids = ids.to_vec();
        full_ids.extend_from_slice(out_ids);
        sessions.commit(
            &format!("s{sid}"),
            &q.msg,
            format!("{text}{out_text}"),
            full_ids,
            out_text,
        );
    }
}

#[test]
fn prop_continuous_batched_decode_token_identical_to_sequential() {
    // THE serving-level exactness property: any randomized interleaving of
    // hit / miss / session requests decoded via the continuous-batching
    // stream API emits exactly the tokens request-at-a-time serving emits.
    check("batched == sequential serving", 20, |rng| {
        let policy = if rng.chance(0.5) {
            RecyclePolicy::Strict
        } else {
            RecyclePolicy::Radix
        };
        // workload: fresh prompts (misses), extensions of earlier prompts
        // (hits), and session turns, in random order ("q"/"base" prefixes
        // keep every prompt non-empty)
        let bases: Vec<String> =
            (0..3).map(|i| format!("base {i} {}", text(rng, 30))).collect();
        let n_req = rng.range(4, 10);
        let reqs: Vec<ReqSpec> = (0..n_req)
            .map(|_| match rng.below(4) {
                0 => ReqSpec {
                    session: None,
                    msg: format!("q {}", text(rng, 40)),
                    max_new: rng.range(1, 5),
                },
                1 => ReqSpec {
                    session: None,
                    msg: rng.choice(&bases).clone(),
                    max_new: rng.range(1, 5),
                },
                2 => {
                    let b = rng.choice(&bases).clone();
                    let suffix = text(rng, 20);
                    ReqSpec {
                        session: None,
                        msg: format!("{b} {suffix}"),
                        max_new: rng.range(1, 5),
                    }
                }
                _ => ReqSpec {
                    session: Some(rng.below(2)),
                    msg: text(rng, 15),
                    max_new: rng.range(1, 4),
                },
            })
            .collect();

        // --- arm 1: sequential (the paper's request-at-a-time loop) ---
        let mut seq = mk_recycler(policy);
        let mut seq_sessions = SessionManager::new();
        let mut expected: Vec<Vec<u32>> = Vec::new();
        for q in &reqs {
            let (ptext, pids, admit_full) = build_prompt(&seq, &seq_sessions, q);
            let out = seq
                .generate_ids(&ptext, pids.clone(), q.max_new, admit_full)
                .map_err(|e| e.to_string())?;
            commit_turn(&mut seq_sessions, q, &ptext, &pids, &out.ids, &out.text);
            expected.push(out.ids);
        }

        // --- arm 2: continuous batching over the same request stream ---
        struct Slot {
            idx: usize,
            text: String,
            ids: Vec<u32>,
            meta: Option<recycle_serve::recycler::ServeMeta>,
            stream: DecodeStream,
        }
        let mut bat = mk_recycler(policy);
        let mut bat_sessions = SessionManager::new();
        let max_batch = rng.range(2, 5);
        let mut pending: VecDeque<usize> = (0..reqs.len()).collect();
        let mut running: Vec<Slot> = Vec::new();
        let mut got: Vec<Option<Vec<u32>>> = (0..reqs.len()).map(|_| None).collect();
        let mut steps = 0usize;
        while got.iter().any(|g| g.is_none()) {
            steps += 1;
            prop_assert!(steps < 10_000, "scheduler did not converge");
            // admission (occasionally skipped to randomize interleavings);
            // a session turn defers while an earlier turn is in flight
            if !rng.chance(0.3) {
                let mut i = 0;
                while running.len() < max_batch && i < pending.len() {
                    let idx = pending[i];
                    let blocked = reqs[idx].session.is_some_and(|sid| {
                        running.iter().any(|s| reqs[s.idx].session == Some(sid))
                    });
                    if blocked {
                        i += 1;
                        continue;
                    }
                    let _ = pending.remove(i);
                    let q = &reqs[idx];
                    let (ptext, pids, admit_full) = build_prompt(&bat, &bat_sessions, q);
                    let Admission { kv, cur_len, meta } =
                        bat.prepare(&ptext, &pids, admit_full);
                    let stream = bat
                        .engine_mut()
                        .start_stream(&pids, kv, cur_len, q.max_new, meta.want_capture)
                        .map_err(|e| e.to_string())?;
                    running.push(Slot {
                        idx,
                        text: ptext,
                        ids: pids,
                        meta: Some(meta),
                        stream,
                    });
                }
            }
            // one batched decode step over every active stream
            if !running.is_empty() {
                let mut refs: Vec<&mut DecodeStream> =
                    running.iter_mut().map(|s| &mut s.stream).collect();
                bat.engine_mut()
                    .step_streams(&mut refs)
                    .map_err(|e| e.to_string())?;
            }
            assert_arena_conserved(bat.arena(), "mid-decode")?;
            // finish
            let mut i = 0;
            while i < running.len() {
                if !running[i].stream.is_finished() {
                    i += 1;
                    continue;
                }
                let mut slot = running.swap_remove(i);
                let meta = slot.meta.take().expect("meta consumed once");
                let out = bat.complete(
                    &slot.text,
                    &slot.ids,
                    meta,
                    slot.stream.into_generated(),
                );
                commit_turn(
                    &mut bat_sessions,
                    &reqs[slot.idx],
                    &slot.text,
                    &slot.ids,
                    &out.ids,
                    &out.text,
                );
                got[slot.idx] = Some(out.ids);
            }
        }
        for (i, (want, g)) in expected.iter().zip(&got).enumerate() {
            let g = g.as_ref().expect("all finished");
            prop_assert!(
                g == want,
                "request {i} diverged under batching: {g:?} vs {want:?}"
            );
        }
        // everything drained: only cache records may still hold blocks
        assert_arena_conserved(bat.arena(), "after drain")?;
        Ok(())
    });
}

#[test]
fn prop_arena_conserved_while_batch_decodes_over_shared_prefix() {
    // N concurrent streams all attached to ONE cached prefix record decode
    // together: block accounting stays conserved at every step, the
    // fully-covered prefix blocks remain physically shared (COW only
    // touches boundary/appended blocks), and the donor record is intact.
    check("shared-prefix batched decode", 30, |rng| {
        let cfg = ModelConfig::nano();
        let mut engine = Engine::new(MockModel::new(cfg.clone()));
        let base = tokens(rng, 9, 60, cfg.vocab_size as u32);
        let mut kv = engine.empty_kv();
        engine.prefill(&base, &mut kv, 0).map_err(|e| e.to_string())?;
        let record = KvRecord::from_view("p", base.clone(), vec![1.0], &kv);
        drop(kv);
        let donor_before = record.kv.to_contiguous();

        let bt = engine.arena().block_tokens();
        let shared_blocks = base.len() / bt; // fully-covered prefix blocks
        let n = rng.range(2, 6);
        let mut streams: Vec<DecodeStream> = Vec::new();
        for _ in 0..n {
            let mut ids = base.clone();
            ids.extend(tokens(rng, 1, 6, cfg.vocab_size as u32));
            let s = engine
                .start_stream(&ids, record.attach(), base.len(), rng.range(1, 6), false)
                .map_err(|e| e.to_string())?;
            streams.push(s);
        }
        loop {
            let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
            let report = engine.step_streams(&mut refs).map_err(|e| e.to_string())?;
            drop(refs);
            assert_arena_conserved(engine.arena(), "decode step")?;
            if report.active == 0 {
                break;
            }
        }
        // the common prefix is ONE physical copy across all streams
        for s in &streams {
            prop_assert!(
                s.kv().block_ids()[..shared_blocks]
                    == record.kv.block_ids()[..shared_blocks],
                "prefix blocks were copied instead of shared"
            );
        }
        prop_assert!(
            record.kv.to_contiguous() == donor_before,
            "donor record mutated by concurrent decode"
        );
        // dropping everything returns every block
        drop(streams);
        drop(record);
        prop_assert!(
            engine.arena().free_blocks() == engine.arena().capacity_blocks(),
            "leak after drain"
        );
        Ok(())
    });
}

// ---------- flat index ----------

#[test]
fn prop_flat_index_top1_matches_brute_force() {
    check("flat index vs brute force", 200, |rng| {
        let dim = 8;
        let mut ix = FlatIndex::new(dim);
        let n = rng.range(1, 30);
        let mut rows: Vec<(u64, Vec<f32>)> = Vec::new();
        for key in 0..n as u64 {
            let v: Vec<f32> = (0..dim).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            ix.add(key, &v);
            rows.push((key, v));
        }
        // random removals
        for _ in 0..rng.below(n / 2 + 1) {
            let i = rng.below(rows.len());
            let (key, _) = rows.remove(i);
            prop_assert!(ix.remove(key), "remove");
        }
        if rows.is_empty() {
            prop_assert!(ix.nearest(&vec![0.0; dim]).is_none(), "empty");
            return Ok(());
        }
        let q: Vec<f32> = (0..dim).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let brute = rows
            .iter()
            .map(|(k, v)| (*k, v.iter().zip(&q).map(|(a, b)| a * b).sum::<f32>()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap();
        let got = ix.nearest(&q).unwrap();
        prop_assert!(
            (got.1 - brute.1).abs() < 1e-5,
            "score {} vs brute {}",
            got.1,
            brute.1
        );
        Ok(())
    });
}

// ---------- json ----------

fn random_json(rng: &mut Rng, depth: usize) -> json::Value {
    use json::Value;
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.chance(0.5)),
        2 => Value::Num((rng.f64() * 2000.0 - 1000.0).round()),
        3 => Value::Str(text(rng, 20)),
        4 => Value::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json roundtrip", 300, |rng| {
        let v = random_json(rng, 3);
        let s = v.to_json();
        let back = json::parse(&s).map_err(|e| format!("{e}: {s}"))?;
        prop_assert!(back == v, "roundtrip: {s}");
        Ok(())
    });
}

// ---------- engine / chunk planning ----------

#[test]
fn prop_plan_chunks_covers_with_bounded_waste() {
    check("plan_chunks", 300, |rng| {
        let mut buckets: Vec<usize> = vec![1];
        let mut b = 1;
        for _ in 0..rng.below(4) {
            b *= rng.range(2, 5);
            buckets.push(b);
        }
        let n = rng.range(1, 300);
        let plan = plan_chunks(&buckets, n);
        let total: usize = plan.iter().sum();
        prop_assert!(total >= n, "undercovered");
        prop_assert!(total - n < *buckets.last().unwrap(), "waste too big");
        prop_assert!(plan.iter().all(|c| buckets.contains(c)), "bad bucket");
        Ok(())
    });
}

// ---------- chunked prefill ----------

#[test]
fn prop_chunked_prefill_equals_inline_any_budget_and_split() {
    // Engine-level half of the chunked-prefill exactness story: for random
    // prompts, random recycled-prefix splits, and random per-step token
    // budgets, prefilling through the suspendable API emits exactly the
    // tokens the inline path emits (chunk-split invariance through the
    // stream API), and each step respects its budget.
    check("chunked prefill == inline (engine)", 80, |rng| {
        let cfg = ModelConfig::nano();
        let prompt = tokens(rng, 2, 120, cfg.vocab_size as u32);
        let split = rng.below(prompt.len());
        let budget = rng.range(1, 70);

        let mut inline_e = Engine::new(MockModel::new(cfg.clone()));
        let mut kv = inline_e.empty_kv();
        if split > 0 {
            inline_e
                .prefill(&prompt[..split], &mut kv, 0)
                .map_err(|e| e.to_string())?;
        }
        let want = inline_e
            .generate(&prompt, kv, split, 6, false)
            .map_err(|e| e.to_string())?;

        let mut e = Engine::new(MockModel::new(cfg.clone()));
        let mut kv2 = e.empty_kv();
        if split > 0 {
            e.prefill(&prompt[..split], &mut kv2, 0)
                .map_err(|e| e.to_string())?;
        }
        let mut p = e
            .start_prefill(&prompt, kv2, split, 6, false)
            .map_err(|e| e.to_string())?;
        while !p.is_done() {
            let prog = e.step_prefill(&mut p, budget).map_err(|e| e.to_string())?;
            prop_assert!(
                (1..=budget).contains(&prog.tokens),
                "budget {budget}: step took {} tokens",
                prog.tokens
            );
        }
        let mut s = e.finish_prefill(p).map_err(|e| e.to_string())?;
        while !s.is_finished() {
            e.step_streams(&mut [&mut s]).map_err(|e| e.to_string())?;
        }
        let g = s.into_generated();
        prop_assert!(
            g.ids == want.ids,
            "diverged at split {split}/{} budget {budget}",
            prompt.len()
        );
        prop_assert!(g.reused_tokens == want.reused_tokens, "reuse depth");
        Ok(())
    });
}

/// Serve a script's requests one at a time through `Recycler::generate_ids`
/// (inline prefill, request-at-a-time — the paper's serving loop), building
/// prompts exactly the way scheduler admission does (`admission_prompt`,
/// including the session sliding window). The per-request expected outputs
/// for the chunked-scheduler arm.
fn sequential_reference(
    policy: RecyclePolicy,
    script: &Script,
) -> Vec<std::result::Result<Vec<u32>, String>> {
    sequential_reference_on(mk_recycler(policy), script)
}

/// [`sequential_reference`] over a caller-built recycler (the chaos suite
/// matches the scheduler arm's arena sizing so both arms see identical
/// resource limits).
fn sequential_reference_on(
    mut seq: Recycler<MockModel>,
    script: &Script,
) -> Vec<std::result::Result<Vec<u32>, String>> {
    let mut sessions = SessionManager::new();
    let mut expected = Vec::new();
    for a in &script.arrivals {
        let (ptext, pids) =
            admission_prompt(&seq, &sessions, a.session.as_deref(), &a.prompt, a.max_new);
        let admit_full = a.session.is_some();
        match seq.generate_ids(&ptext, pids.clone(), a.max_new, admit_full) {
            Ok(out) => {
                if let Some(sid) = &a.session {
                    let mut full_ids = pids;
                    full_ids.extend_from_slice(&out.ids);
                    sessions.commit(
                        sid,
                        &a.prompt,
                        format!("{ptext}{}", out.text),
                        full_ids,
                        &out.text,
                    );
                }
                expected.push(Ok(out.ids));
            }
            Err(e) => expected.push(Err(e.to_string())),
        }
    }
    expected
}

/// Per-request stream contract over a [`TraceRun`] (the harness attaches
/// a stream channel to every request): each captured event sequence must
/// be zero or more `Token`s followed by exactly one terminal `End` that
/// mirrors the aggregate reply, and the reassembled token ids — applying
/// the client's truncate-on-regression discipline, so transient-retry
/// replays are legal — must equal the aggregate output exactly.
fn stream_contract(run: &TraceRun) -> std::result::Result<(), String> {
    for (i, events) in run.streams.iter().enumerate() {
        let mut ids: Vec<u32> = Vec::new();
        let mut end: Option<&Response> = None;
        for ev in events {
            match ev {
                StreamEvent::Token { index, id, .. } => {
                    if end.is_some() {
                        return Err(format!("request {i}: token event after End"));
                    }
                    if *index > ids.len() {
                        return Err(format!(
                            "request {i}: token index {index} skips ahead of {}",
                            ids.len()
                        ));
                    }
                    ids.truncate(*index);
                    ids.push(*id);
                }
                StreamEvent::End(resp) => {
                    if end.is_some() {
                        return Err(format!("request {i}: second End event"));
                    }
                    end = Some(resp);
                }
            }
        }
        let Some(end) = end else {
            return Err(format!("request {i}: stream never terminated"));
        };
        match (&run.outputs[i], end) {
            (Ok(out), Response::Ok(o)) => {
                if &o.ids != out {
                    return Err(format!(
                        "request {i}: End outcome diverges from the aggregate reply"
                    ));
                }
                if &ids != out {
                    return Err(format!(
                        "request {i}: streamed ids {ids:?} != aggregate output {out:?}"
                    ));
                }
            }
            (Err(_), Response::Err { .. }) => {}
            (want, got) => {
                return Err(format!(
                    "request {i}: End event disagrees with the aggregate reply: \
                     aggregate {want:?} vs End {got:?}"
                ));
            }
        }
    }
    Ok(())
}

/// Run the chunked-prefill scheduler over `script` and compare every
/// request's tokens against the sequential reference. `Err` carries the
/// first mismatch (or a non-converging run) — the shrink predicate.
fn chunked_vs_sequential(
    policy: RecyclePolicy,
    cfg: &ServerConfig,
    script: &Script,
) -> std::result::Result<TraceRun, String> {
    let expected = sequential_reference(policy, script);
    let run = run_script(|| mk_recycler(policy), cfg.clone(), script, 50_000)?;
    for (i, (want, got)) in expected.iter().zip(&run.outputs).enumerate() {
        match (want, got) {
            (Ok(w), Ok(g)) if w == g => {}
            (Err(_), Err(_)) => {}
            _ => {
                return Err(format!(
                    "request {i} diverged: sequential {want:?} vs chunked {got:?}"
                ))
            }
        }
    }
    stream_contract(&run)?;
    Ok(run)
}

#[test]
fn prop_chunked_prefill_scheduler_token_identical_to_sequential() {
    // THE chunked-prefill exactness property: any randomized schedule of
    // fresh / extension / session arrivals, served by the tick-driven
    // scheduler under a random chunk budget and prefill-slot count, emits
    // for EVERY stream exactly the tokens inline request-at-a-time serving
    // emits. Cache hit/miss decisions may differ between the arms (the
    // interleaving changes what is cached when) — outputs must not, which
    // is the paper's whole claim. On failure, the trace harness shrinks
    // the schedule to a minimal reproduction before panicking.
    check("chunked-prefill scheduler == sequential", 12, |rng| {
        let policy = if rng.chance(0.5) {
            RecyclePolicy::Strict
        } else {
            RecyclePolicy::Radix
        };
        let bases: Vec<String> =
            (0..3).map(|i| format!("base {i} {}", text(rng, 30))).collect();
        let n_req = rng.range(4, 10);
        let mut arrivals: Vec<Arrival> = (0..n_req)
            .map(|_| {
                let at_tick = rng.below(8);
                match rng.below(4) {
                    0 => Arrival {
                        at_tick,
                        prompt: format!("q {}", text(rng, 40)),
                        max_new: rng.range(1, 5),
                        session: None,
                    },
                    1 => Arrival {
                        at_tick,
                        prompt: rng.choice(&bases).clone(),
                        max_new: rng.range(1, 5),
                        session: None,
                    },
                    2 => {
                        let b = rng.choice(&bases).clone();
                        let suffix = text(rng, 20);
                        Arrival {
                            at_tick,
                            prompt: format!("{b} {suffix}"),
                            max_new: rng.range(1, 5),
                            session: None,
                        }
                    }
                    _ => Arrival {
                        at_tick,
                        prompt: format!("m {}", text(rng, 14)),
                        max_new: rng.range(1, 4),
                        session: Some(format!("s{}", rng.below(2))),
                    },
                }
            })
            .collect();
        // stable sort: delivery order == script order == the sequential
        // arm's serving order (per-session turn order must agree)
        arrivals.sort_by_key(|a| a.at_tick);
        let script = Script { arrivals };
        let cfg = ServerConfig {
            max_batch: rng.range(2, 5),
            prefill_chunk_tokens: rng.range(1, 48),
            max_prefilling_slots: rng.range(1, 3),
            ..Default::default()
        };
        match chunked_vs_sequential(policy, &cfg, &script) {
            Ok(run) => {
                // budget discipline: no single prefill step exceeds the
                // chunk budget, and the per-tick stall bound holds
                for (_, ev) in &run.events {
                    if let SchedEvent::PrefillChunk { tokens, .. } = ev {
                        prop_assert!(
                            *tokens <= cfg.prefill_chunk_tokens,
                            "chunk of {tokens} tokens exceeds budget {}",
                            cfg.prefill_chunk_tokens
                        );
                    }
                }
                let cap =
                    (cfg.prefill_chunk_tokens * cfg.max_prefilling_slots) as u64;
                prop_assert!(
                    run.stats.prefill_stall_tokens_max <= cap,
                    "stall {} tokens exceeds budget*slots {cap}",
                    run.stats.prefill_stall_tokens_max
                );
                Ok(())
            }
            Err(msg) => {
                let minimal = shrink_script(&script, |s| {
                    chunked_vs_sequential(policy, &cfg, s).is_err()
                });
                prop_assert!(
                    false,
                    "{msg}\nminimal failing script: {minimal:?}\n\
                     cfg: chunk_tokens={} prefill_slots={} max_batch={}",
                    cfg.prefill_chunk_tokens,
                    cfg.max_prefilling_slots,
                    cfg.max_batch
                );
                Ok(())
            }
        }
    });
}

#[test]
fn prop_recycled_equals_baseline_any_split() {
    // the paper's claim over random prompts and random split points,
    // through the full engine (mock model)
    check("recycled == baseline", 60, |rng| {
        let cfg = ModelConfig::nano();
        let mut engine = Engine::new(MockModel::new(cfg.clone()));
        let prompt = tokens(rng, 2, 60, cfg.vocab_size as u32);
        let split = rng.range(1, prompt.len());
        let base = engine
            .generate(&prompt, engine.empty_kv(), 0, 6, false)
            .map_err(|e| e.to_string())?;
        let mut kv = engine.empty_kv();
        engine
            .prefill(&prompt[..split], &mut kv, 0)
            .map_err(|e| e.to_string())?;
        let rec = engine
            .generate(&prompt, kv, split, 6, false)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            rec.ids == base.ids,
            "diverged at split {split}/{} ",
            prompt.len()
        );
        Ok(())
    });
}

#[test]
fn prop_streamed_tokens_identical_to_aggregate_and_reference() {
    // THE streaming-identity property, end to end through the trace
    // harness: for random workloads, every request's streamed events
    // reassemble to exactly the aggregate reply (ids AND incremental
    // text — the decoder's end-of-stream flush makes text byte-exact
    // even when a token splits a UTF-8 character), and both equal the
    // sequential no-fault reference. The CI slow lane runs this at 10x
    // via PALLAS_PROP_CASES; failures print a PALLAS_PROP_SEED repro.
    check("streamed == aggregate == sequential reference", 10, |rng| {
        let script = random_workload(rng);
        let cfg = ServerConfig {
            max_batch: rng.range(2, 5),
            prefill_chunk_tokens: rng.range(1, 48),
            max_prefilling_slots: rng.range(1, 3),
            ..Default::default()
        };
        let reference = sequential_reference(RecyclePolicy::Strict, &script);
        let run = run_script(
            || mk_recycler(RecyclePolicy::Strict),
            cfg.clone(),
            &script,
            50_000,
        )?;
        stream_contract(&run)?;
        for (i, events) in run.streams.iter().enumerate() {
            let concat: String = events
                .iter()
                .filter_map(|ev| match ev {
                    StreamEvent::Token { text, .. } => Some(text.as_str()),
                    StreamEvent::End(_) => None,
                })
                .collect();
            match events.last() {
                Some(StreamEvent::End(Response::Ok(o))) => {
                    prop_assert!(
                        concat == o.text,
                        "request {i}: streamed text {concat:?} != aggregate {:?}",
                        o.text
                    );
                    prop_assert!(
                        matches!(&reference[i], Ok(w) if *w == o.ids),
                        "request {i}: diverged from the sequential reference: \
                         streamed {:?} vs reference {:?}",
                        o.ids,
                        reference[i]
                    );
                }
                Some(StreamEvent::End(Response::Err { .. })) => {
                    prop_assert!(
                        reference[i].is_err(),
                        "request {i}: stream failed but the fault-free reference succeeded"
                    );
                }
                other => {
                    prop_assert!(false, "request {i}: stream did not end with End: {other:?}");
                }
            }
        }
        Ok(())
    });
}

// ---------- chaos: fault injection vs the serving contract ----------

/// The scheduler arm of a chaos run: mock backend + spill tier + arena all
/// share one installed fault plan. The arena is caller-owned so its block
/// accounting can be audited after the scheduler (and the recycler inside
/// it) has been dropped.
fn mk_chaos_recycler(arena: &KvArena, h: &FaultHandle) -> Recycler<MockModel> {
    let mut r = Recycler::new(
        Engine::with_arena(
            MockModel::new(ModelConfig::nano()).with_faults(h.clone()),
            arena.clone(),
        ),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        CacheConfig {
            // small hot tier + a cold tier so random workloads actually
            // evict, spill, and reload — the SpillWrite/Read/Torn sites
            // see traffic instead of idling
            max_entries: 4,
            max_spill_bytes: 1 << 20,
            ..Default::default()
        },
        RecyclePolicy::Strict,
    );
    r.install_faults(h.clone());
    r
}

/// A randomized serving workload (fresh prompts, shared-prefix repeats and
/// extensions, two interleaved sessions) — the same shape the
/// chunked-prefill exactness property drives.
fn random_workload(rng: &mut Rng) -> Script {
    let bases: Vec<String> =
        (0..3).map(|i| format!("base {i} {}", text(rng, 30))).collect();
    let n_req = rng.range(4, 10);
    let mut arrivals: Vec<Arrival> = (0..n_req)
        .map(|_| {
            let at_tick = rng.below(8);
            match rng.below(4) {
                0 => Arrival {
                    at_tick,
                    prompt: format!("q {}", text(rng, 40)),
                    max_new: rng.range(1, 5),
                    session: None,
                },
                1 => Arrival {
                    at_tick,
                    prompt: rng.choice(&bases).clone(),
                    max_new: rng.range(1, 5),
                    session: None,
                },
                2 => {
                    let b = rng.choice(&bases).clone();
                    let suffix = text(rng, 20);
                    Arrival {
                        at_tick,
                        prompt: format!("{b} {suffix}"),
                        max_new: rng.range(1, 5),
                        session: None,
                    }
                }
                _ => Arrival {
                    at_tick,
                    prompt: format!("m {}", text(rng, 14)),
                    max_new: rng.range(1, 4),
                    session: Some(format!("s{}", rng.below(2))),
                },
            }
        })
        .collect();
    // stable sort: delivery order == script order == the sequential arm's
    // serving order (per-session turn order must agree between the arms)
    arrivals.sort_by_key(|a| a.at_tick);
    Script { arrivals }
}

/// A random fault plan over the tick-safe sites. The slow sites are left
/// out (wall-clock stalls add nothing to a tick-driven run); permanent and
/// arena rates stay low so most requests still exercise a full lifecycle
/// rather than dying at admission.
fn random_fault_plan(rng: &mut Rng) -> FaultPlan {
    let mut plan = FaultPlan::new(rng.next_u64());
    if rng.chance(0.8) {
        plan = plan.with_rate(FaultSite::ModelTransient, 0.03 * rng.below(4) as f64);
    }
    if rng.chance(0.3) {
        plan = plan.with_rate(FaultSite::ModelPermanent, 0.02);
    }
    if rng.chance(0.5) {
        plan = plan.with_rate(FaultSite::SpillWrite, 0.1 * rng.below(4) as f64);
    }
    if rng.chance(0.5) {
        plan = plan.with_rate(FaultSite::SpillRead, 0.1 * rng.below(4) as f64);
    }
    if rng.chance(0.5) {
        plan = plan.with_rate(FaultSite::SpillTorn, 0.1 * rng.below(4) as f64);
    }
    if rng.chance(0.4) {
        plan = plan.with_rate(FaultSite::ArenaSpike, 0.02 * rng.below(3) as f64);
    }
    if rng.chance(0.3) {
        // pinpoint strike early in the run, on top of any rates
        plan = plan.script(FaultSite::ModelTransient, &[rng.range(1, 30) as u64]);
    }
    plan
}

/// One chaos run, asserting the full failure contract from
/// `coordinator/mod.rs` ("Failure semantics"):
///
/// 1. **termination** — the run converges within the tick bound;
/// 2. **exactly one reply** per request (no dropped reply channels);
/// 3. **exactly one terminal stream event** per request, with token
///    events strictly before it and reassembling to the reply's ids;
/// 4. **arena conservation** — blocks stay conserved and fully drain once
///    the scheduler is gone, however the fault schedule interleaved;
/// 5. **fault-free identity** — every request that still succeeded emits
///    exactly the tokens an undisturbed sequential run emits (retries and
///    cache-path faults are invisible in the output stream).
///
/// `Err` carries the first violation — also the shrink predicate.
fn chaos_contract(
    plan: &FaultPlan,
    cfg: &ServerConfig,
    script: &Script,
) -> std::result::Result<(), String> {
    let arena = KvArena::new(&ModelConfig::nano(), 8, 512);
    let h = plan.clone().install();
    let run = run_script(|| mk_chaos_recycler(&arena, &h), cfg.clone(), script, 50_000)?;
    for (i, o) in run.outputs.iter().enumerate() {
        if let Err(m) = o {
            if m.contains("dropped without reply") || m.contains("never completed") {
                return Err(format!("request {i} broke the one-reply contract: {m}"));
            }
        }
    }
    // the stream-side mirror of the one-reply contract: exactly one End
    // per request, tokens strictly before it, and the reassembled ids
    // (truncate-on-regression for retry replays) equal to the reply —
    // however the fault schedule interleaved
    stream_contract(&run)?;
    assert_arena_conserved(&arena, "after chaos run")?;
    if arena.free_blocks() != arena.capacity_blocks() {
        return Err(format!(
            "block leak: {} of {} blocks still held after the scheduler drained",
            arena.used_blocks(),
            arena.capacity_blocks()
        ));
    }
    // fault-free identity, against a sequential run with the same arena
    // sizing and no plan installed; a session is only comparable up to its
    // first faulted turn (later turns legitimately see a shorter
    // transcript than the undisturbed run)
    let reference = sequential_reference_on(
        mk_chaos_recycler(
            &KvArena::new(&ModelConfig::nano(), 8, 512),
            &FaultHandle::off(),
        ),
        script,
    );
    let mut tainted: HashSet<&str> = HashSet::new();
    for (i, a) in script.arrivals.iter().enumerate() {
        if let Some(s) = &a.session {
            if tainted.contains(s.as_str()) {
                continue;
            }
            if run.outputs[i].is_err() {
                tainted.insert(s.as_str());
                continue;
            }
        }
        if let Ok(got) = &run.outputs[i] {
            match &reference[i] {
                Ok(want) if want == got => {}
                other => {
                    return Err(format!(
                        "request {i} survived faults but diverged: \
                         faulted run {got:?} vs fault-free {other:?}"
                    ))
                }
            }
        }
    }
    Ok(())
}

#[test]
fn prop_chaos_random_faults_keep_the_serving_contract() {
    // THE chaos property: a random workload under a seeded random fault
    // plan never wedges the scheduler, never drops a reply, conserves the
    // arena, and leaves every surviving request token-identical to an
    // undisturbed run. Failures print the seed (via the prop harness), the
    // fault plan, and a shrunk minimal script.
    check("chaos: faults vs serving contract", 10, |rng| {
        let script = random_workload(rng);
        let plan = random_fault_plan(rng);
        let cfg = ServerConfig {
            max_batch: rng.range(2, 5),
            prefill_chunk_tokens: rng.range(1, 48),
            max_prefilling_slots: rng.range(1, 3),
            ..Default::default()
        };
        if let Err(msg) = chaos_contract(&plan, &cfg, &script) {
            let minimal =
                shrink_script(&script, |s| chaos_contract(&plan, &cfg, s).is_err());
            prop_assert!(
                false,
                "{msg}\nminimal failing script: {minimal:?}\nplan: {plan:?}\n\
                 cfg: chunk_tokens={} prefill_slots={} max_batch={}",
                cfg.prefill_chunk_tokens,
                cfg.max_prefilling_slots,
                cfg.max_batch
            );
        }
        Ok(())
    });
}

#[test]
fn chaos_smoke_fixed_seed() {
    // Fast-lane pin: one known-seed chaos case (well under a second) so
    // the default `cargo test -q` always exercises the fault machinery
    // end to end; the scheduled slow lane runs the full property at 10x.
    let mut rng = Rng::new(0xC4A05);
    let script = random_workload(&mut rng);
    let plan = FaultPlan::new(0xFA17)
        .with_rate(FaultSite::ModelTransient, 0.05)
        .with_rate(FaultSite::SpillRead, 0.2)
        .with_rate(FaultSite::SpillTorn, 0.2)
        .with_rate(FaultSite::ArenaSpike, 0.02)
        .script(FaultSite::ModelPermanent, &[40]);
    let cfg = ServerConfig {
        max_batch: 3,
        prefill_chunk_tokens: 16,
        max_prefilling_slots: 2,
        ..Default::default()
    };
    if let Err(msg) = chaos_contract(&plan, &cfg, &script) {
        panic!("fixed-seed chaos smoke failed: {msg}");
    }
}

// ---------- sharded routing ----------

#[test]
fn prop_routing_placement_never_changes_tokens() {
    // The router's contract: placement changes latency and hit rate,
    // NEVER tokens. One seeded multi-tenant trace (bursty arrivals,
    // heavy-tailed session reuse, mixed prompt lengths — the same
    // generator the sharding ablation bench drives) is served under
    // N=1, N=3 round-robin, and N=3 prefix-affinity; every request's
    // output ids must be identical across all placements, and every
    // worker arena must conserve blocks with zero leaks after shutdown.
    check("routing invariance", 5, |rng| {
        let trace = multi_tenant_trace(TraceSpec {
            tenants: 3,
            requests: 18,
            mean_burst: 3,
            session_reuse: 0.4,
            min_words: 2,
            max_words: 10,
            max_new_tokens: 4,
            seed: rng.next_u64(),
        });
        let arms = [
            (1usize, RoutingPolicy::PrefixAffinity),
            (3, RoutingPolicy::RoundRobin),
            (3, RoutingPolicy::PrefixAffinity),
        ];
        let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
        for (n, routing) in arms {
            let cfg = ModelConfig::nano();
            // caller-owned arenas: conservation stays checkable after the
            // workers (and their recyclers) are gone
            let arenas: Vec<KvArena> =
                (0..n).map(|_| KvArena::new(&cfg, 16, 256)).collect();
            let worker_arenas = arenas.clone();
            let c = Coordinator::spawn(
                move |w| {
                    Recycler::new(
                        Engine::with_arena(
                            MockModel::new(ModelConfig::nano()),
                            worker_arenas[w].clone(),
                        ),
                        Arc::new(Tokenizer::new(vec![])),
                        Box::new(NgramEmbedder::new(64)),
                        CacheConfig::default(),
                        RecyclePolicy::Strict,
                    )
                },
                ServerConfig {
                    num_workers: n,
                    routing,
                    queue_capacity: 1024,
                    ..Default::default()
                },
            );
            let mut ids = Vec::new();
            for r in &trace {
                let out = match &r.session {
                    Some(s) => c.chat(s, &r.prompt, r.max_new_tokens),
                    None => c.generate(&r.prompt, r.max_new_tokens),
                };
                match out {
                    Ok(o) => ids.push(o.ids),
                    Err(e) => prop_assert!(false, "arm n={n} {routing:?} failed: {e}"),
                }
            }
            c.shutdown();
            for (w, arena) in arenas.iter().enumerate() {
                assert_arena_conserved(arena, &format!("worker {w} after shutdown"))?;
                prop_assert!(
                    arena.free_blocks() == arena.capacity_blocks(),
                    "worker {w} leaked {} blocks (n={n}, {routing:?})",
                    arena.capacity_blocks() - arena.free_blocks()
                );
            }
            outputs.push(ids);
        }
        prop_assert!(
            outputs[0] == outputs[1],
            "round-robin placement diverged from single-worker tokens"
        );
        prop_assert!(
            outputs[0] == outputs[2],
            "prefix-affinity placement diverged from single-worker tokens"
        );
        Ok(())
    });
}

// ---------- segment tier (tier-2 recycling) ----------

/// `mk_recycler` with a caller-chosen cache config (the segment-tier
/// properties vary the stride and budget).
fn mk_recycler_cache(policy: RecyclePolicy, cache: CacheConfig) -> Recycler<MockModel> {
    Recycler::new(
        Engine::new(MockModel::new(ModelConfig::nano())),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        cache,
        policy,
    )
}

#[test]
fn prop_zero_budget_segment_tier_is_byte_identical_to_exact_only() {
    // The fidelity-budget contract: budget 0.0 must leave the recycler
    // byte-identical to an exact-prefix-only build — same outputs AND
    // same error outcomes — across random workloads, under both lookup
    // policies, even with a nonzero indexing stride configured.
    check("segment budget-0 identity", 40, |rng| {
        let script = random_workload(rng);
        let stride = rng.range(2, 12);
        for policy in [RecyclePolicy::Strict, RecyclePolicy::Radix] {
            let exact = sequential_reference_on(mk_recycler(policy), &script);
            let gated = sequential_reference_on(
                mk_recycler_cache(
                    policy,
                    CacheConfig {
                        max_entries: 8,
                        segment_tokens: stride,
                        segment_fidelity_budget: 0.0,
                        ..Default::default()
                    },
                ),
                &script,
            );
            prop_assert!(
                exact == gated,
                "budget 0 diverged from exact-only under {policy:?} (stride {stride})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_segment_reanchor_conserves_arena_and_tokens() {
    // Offset-shifted shared-document prompts force tier-2 hits: fresh
    // prefilled heads + re-anchored cached spans + COW decode extensions
    // all mix in one arena. Conservation must hold after every request,
    // every block must return once the recycler is gone, and — the mock
    // backend's KV being content-addressed — served tokens must equal the
    // cold baseline's.
    let cfg = ModelConfig::nano();
    check("segment re-anchor conservation", 30, |rng| {
        let doc = format!("shared document {}", text(rng, 50));
        let arena = KvArena::new(&cfg, 8, 512);
        let mut r = Recycler::new(
            Engine::with_arena(MockModel::new(cfg.clone()), arena.clone()),
            Arc::new(Tokenizer::new(vec![])),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 0, // unbounded: the doc record must survive
                segment_tokens: rng.range(4, 10),
                segment_fidelity_budget: 0.2,
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        let mut base = mk_recycler(RecyclePolicy::Off);
        let mut doc_requests = 0;
        for i in 0..rng.range(4, 9) {
            let prompt = if rng.below(3) == 0 {
                format!("fresh {}", text(rng, 20))
            } else {
                doc_requests += 1;
                format!("head {i} {} {doc}", text(rng, 8))
            };
            let max_new = rng.range(1, 4);
            let out = r.generate(&prompt, max_new);
            prop_assert!(out.is_ok(), "segment arm failed: {out:?}");
            let want = base.generate(&prompt, max_new);
            prop_assert!(want.is_ok(), "baseline arm failed: {want:?}");
            prop_assert!(
                out.unwrap().ids == want.unwrap().ids,
                "segment serving changed tokens on {prompt:?}"
            );
            assert_arena_conserved(&arena, "after request")?;
        }
        let stats = r.store().stats();
        if doc_requests >= 2 {
            prop_assert!(
                stats.segment_hits >= 1,
                "{doc_requests} shifted doc requests produced no segment hit"
            );
        }
        drop(r);
        assert_arena_conserved(&arena, "after drop")?;
        prop_assert!(
            arena.free_blocks() == arena.capacity_blocks(),
            "re-anchored serving leaked {} blocks",
            arena.capacity_blocks() - arena.free_blocks()
        );
        Ok(())
    });
}
