//! Integration: coordinator + TCP server over the line-delimited JSON
//! protocol (mock model — no artifacts needed).

use std::sync::Arc;

use recycle_serve::config::{ModelConfig, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::server::{Server, TcpClient};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;

fn spawn_stack() -> (Arc<Coordinator>, Server) {
    let coordinator = Arc::new(Coordinator::spawn(
        || {
            Recycler::new(
                Engine::new(MockModel::new(ModelConfig::nano())),
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                Default::default(),
                RecyclePolicy::Strict,
            )
        },
        ServerConfig::default(),
    ));
    // port 0: the OS picks a free port
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").unwrap();
    (coordinator, server)
}

#[test]
fn end_to_end_request_over_tcp() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let resp = client
        .request("hello from the network client", 4, None)
        .unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(resp.get("output").and_then(|v| v.as_str()).is_some());
    assert_eq!(resp.get("new_tokens").and_then(|v| v.as_i64()), Some(4));
    server.stop();
}

#[test]
fn recycling_visible_over_the_wire() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let a = client
        .request("what is the capital of france?", 3, None)
        .unwrap();
    assert_eq!(a.get("cache_hit").and_then(|v| v.as_bool()), Some(false));
    let b = client
        .request("what is the capital of france? and italy?", 3, None)
        .unwrap();
    assert_eq!(b.get("cache_hit").and_then(|v| v.as_bool()), Some(true));
    assert!(b.get("reuse_depth").and_then(|v| v.as_i64()).unwrap() > 0);
    server.stop();
}

#[test]
fn malformed_request_gets_error_not_disconnect() {
    let (_c, server) = spawn_stack();
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"));
    // connection still usable
    w.write_all(br#"{"prompt": "still alive", "max_new_tokens": 2}"#)
        .unwrap();
    w.write_all(b"\n").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"));
    server.stop();
}

#[test]
fn session_chat_over_tcp() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let t1 = client.request("hello there", 3, Some("s1")).unwrap();
    assert_eq!(t1.get("cache_hit").and_then(|v| v.as_bool()), Some(false));
    let t2 = client.request("tell me more", 3, Some("s1")).unwrap();
    assert_eq!(
        t2.get("cache_hit").and_then(|v| v.as_bool()),
        Some(true),
        "turn 2 must recycle the session transcript"
    );
    server.stop();
}

#[test]
fn multiple_clients_share_the_coordinator() {
    let (c, server) = spawn_stack();
    let addr = server.addr();
    let mut handles = Vec::new();
    for i in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut client = TcpClient::connect(addr).unwrap();
            let r = client
                .request(&format!("client {i} asking a question"), 2, None)
                .unwrap();
            assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(c.stats().completed >= 3);
    server.stop();
}
