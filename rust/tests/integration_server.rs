//! Integration: coordinator + TCP server over the line-delimited JSON
//! protocol, and the chunked-prefill head-of-line regression suite
//! (mock model — no artifacts needed).

use std::sync::Arc;

use recycle_serve::config::{ModelConfig, ServerConfig};
use recycle_serve::coordinator::{Coordinator, SchedEvent};
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::server::{Server, TcpClient};
use recycle_serve::testutil::trace::{run_script, Arrival, Script};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;

/// Worker count for the shared stack: CI runs this whole suite at both
/// `RECYCLE_NUM_WORKERS=1` (the behavior-preserving default) and `=4`
/// (the sharded router path) — every wire-level contract here must hold
/// under any placement.
fn num_workers_from_env() -> usize {
    std::env::var("RECYCLE_NUM_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

fn spawn_stack_with(cfg: ServerConfig) -> (Arc<Coordinator>, Server) {
    let coordinator = Arc::new(Coordinator::spawn(
        |_worker| {
            Recycler::new(
                Engine::new(MockModel::new(ModelConfig::nano())),
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                Default::default(),
                RecyclePolicy::Strict,
            )
        },
        cfg,
    ));
    // port 0: the OS picks a free port
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").unwrap();
    (coordinator, server)
}

fn spawn_stack() -> (Arc<Coordinator>, Server) {
    spawn_stack_with(ServerConfig {
        num_workers: num_workers_from_env(),
        ..Default::default()
    })
}

#[test]
fn end_to_end_request_over_tcp() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let resp = client
        .request("hello from the network client", 4, None)
        .unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(resp.get("output").and_then(|v| v.as_str()).is_some());
    assert_eq!(resp.get("new_tokens").and_then(|v| v.as_i64()), Some(4));
    server.stop();
}

#[test]
fn recycling_visible_over_the_wire() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let a = client
        .request("what is the capital of france?", 3, None)
        .unwrap();
    assert_eq!(a.get("cache_hit").and_then(|v| v.as_bool()), Some(false));
    let b = client
        .request("what is the capital of france? and italy?", 3, None)
        .unwrap();
    assert_eq!(b.get("cache_hit").and_then(|v| v.as_bool()), Some(true));
    assert!(b.get("reuse_depth").and_then(|v| v.as_i64()).unwrap() > 0);
    server.stop();
}

#[test]
fn malformed_request_gets_error_not_disconnect() {
    let (_c, server) = spawn_stack();
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"));
    // the reply carries the machine-readable taxonomy label
    assert!(
        line.contains("\"error_kind\":\"json\""),
        "missing error_kind: {line}"
    );
    // connection still usable
    w.write_all(br#"{"prompt": "still alive", "max_new_tokens": 2}"#)
        .unwrap();
    w.write_all(b"\n").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"));
    server.stop();
}

#[test]
fn invalid_utf8_line_gets_typed_error_and_connection_survives() {
    // A client pushing raw non-UTF-8 bytes must get a typed error reply on
    // the same connection — not a silent disconnect (the pre-hardening
    // `lines()` framing folded invalid UTF-8 into Err and dropped the
    // stream).
    let (_c, server) = spawn_stack();
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(b"\xff\xfe not utf8 \x80\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "bad reply: {line}");
    assert!(
        line.contains("\"error_kind\":\"json\""),
        "missing error_kind: {line}"
    );
    assert!(line.contains("UTF-8"), "unhelpful message: {line}");
    // same connection, valid request: still served
    w.write_all(br#"{"prompt": "after the garbage", "max_new_tokens": 2}"#)
        .unwrap();
    w.write_all(b"\n").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "connection died: {line}");
    server.stop();
}

#[test]
fn scheduler_errors_keep_their_kind_on_the_wire() {
    // A serving-path failure must reach the client with its taxonomy
    // label, not collapse into a generic rejection: an over-window prompt
    // fails admission with `prompt_too_long`.
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let long = "w".repeat(4 * ModelConfig::nano().max_seq);
    let resp = client.request(&long, 2, None).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
    let kind = resp
        .get("error_kind")
        .and_then(|v| v.as_str())
        .unwrap_or_default()
        .to_string();
    assert!(
        kind == "prompt_too_long" || kind == "context_exhausted",
        "expected an admission kind, got {kind:?}: {}",
        resp.to_json()
    );
    server.stop();
}

#[test]
fn client_disconnect_mid_line_leaves_server_serving() {
    // A client that dies mid-request-line (no trailing newline) must only
    // kill its own connection thread; the accept loop and other clients
    // keep working.
    let (_c, server) = spawn_stack();
    {
        use std::io::Write;
        let mut w = std::net::TcpStream::connect(server.addr()).unwrap();
        w.write_all(br#"{"prompt": "I will never finish this li"#)
            .unwrap();
        // dropped here: EOF mid-line on the server side
    }
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let resp = client.request("a well behaved request", 2, None).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    server.stop();
}

#[test]
fn session_chat_over_tcp() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    let t1 = client.request("hello there", 3, Some("s1")).unwrap();
    assert_eq!(t1.get("cache_hit").and_then(|v| v.as_bool()), Some(false));
    let t2 = client.request("tell me more", 3, Some("s1")).unwrap();
    assert_eq!(
        t2.get("cache_hit").and_then(|v| v.as_bool()),
        Some(true),
        "turn 2 must recycle the session transcript"
    );
    server.stop();
}

#[test]
fn head_of_line_stall_bounded_by_prefill_chunk_budget() {
    // Regression for the PR-2 scheduler's head-of-line blocking: one
    // max-window, cache-cold prompt arriving mid-decode used to run its
    // WHOLE prefill inline at admission, stalling every in-flight stream
    // for the full prompt. With chunked prefill the in-flight streams
    // must advance every single tick while the long prompt prefills, and
    // no tick may carry more than `prefill_chunk_tokens` of prefill work.
    // Driven tick-by-tick through the deterministic trace harness — no
    // wall-clock anywhere.
    let budget = 16usize;
    let long_len = 200usize; // tokens (byte tokenizer), well past budget
    let script = Script {
        arrivals: vec![
            Arrival {
                at_tick: 0,
                prompt: "aa bb cc dd".into(),
                max_new: 40,
                session: None,
            },
            Arrival {
                at_tick: 0,
                prompt: "ee ff gg hh".into(),
                max_new: 40,
                session: None,
            },
            Arrival {
                at_tick: 2,
                prompt: "z".repeat(long_len),
                max_new: 4,
                session: None,
            },
        ],
    };
    let cfg = ServerConfig {
        max_batch: 8,
        prefill_chunk_tokens: budget,
        populate_cache: false,
        ..Default::default()
    };
    let mk = || {
        Recycler::new(
            Engine::new(MockModel::new(ModelConfig::nano())),
            Arc::new(Tokenizer::new(vec![])),
            Box::new(NgramEmbedder::new(64)),
            Default::default(),
            RecyclePolicy::Strict,
        )
    };
    let run = run_script(mk, cfg, &script, 10_000).unwrap();
    assert!(run.outputs.iter().all(|o| o.is_ok()), "{:?}", run.outputs);
    assert_eq!(run.outputs[2].as_ref().unwrap().len(), 4);

    // the long prompt's prefill spans many ticks...
    let admitted = run
        .first_tick_where(|e| matches!(e, SchedEvent::Admitted { id: 3 }))
        .expect("long prompt admitted");
    let prefill_done = run
        .first_tick_where(|e| matches!(e, SchedEvent::PrefillChunk { id: 3, done: true, .. }))
        .expect("long prompt finished prefill");
    assert!(
        prefill_done - admitted + 1 >= long_len / budget,
        "200 tokens at {budget}/tick must span >= {} ticks, took {}",
        long_len / budget,
        prefill_done - admitted + 1
    );
    // ...and during EVERY one of those ticks both in-flight streams
    // advanced (a decode dispatch with occupancy >= 2 — no stall at all,
    // let alone an unbounded one)
    for t in admitted..=prefill_done {
        assert!(
            run.tick_events(t).iter().any(|e| matches!(
                e,
                SchedEvent::DecodeStep { occupancy } if *occupancy >= 2
            )),
            "tick {t}: in-flight decode stalled while the long prompt prefilled"
        );
    }
    // per-tick prefill work is bounded by the chunk budget (the
    // SchedulerStats counter the coordinator surfaces)
    for (_, ev) in &run.events {
        if let SchedEvent::PrefillChunk { tokens, .. } = ev {
            assert!(*tokens <= budget, "chunk of {tokens} tokens > budget {budget}");
        }
    }
    assert!(
        run.stats.prefill_stall_tokens_max <= budget as u64,
        "stall counter {} exceeds the chunk budget {budget}",
        run.stats.prefill_stall_tokens_max
    );
    assert!(run.stats.prefill_ticks as usize >= long_len / budget);
}

#[test]
fn coordinator_surfaces_chunked_prefill_counters() {
    // Wire-level smoke: the same counters flow through CoordinatorStats
    // when the worker thread drives the scheduler. The stall bound holds
    // structurally whatever the thread timing does.
    let budget = 16usize;
    let coordinator = Coordinator::spawn(
        |_worker| {
            Recycler::new(
                Engine::new(MockModel::new(ModelConfig::nano())),
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                Default::default(),
                RecyclePolicy::Strict,
            )
        },
        ServerConfig {
            max_batch: 4,
            prefill_chunk_tokens: budget,
            populate_cache: false,
            ..Default::default()
        },
    );
    // a long-decode request to keep streams in flight, then a cold
    // 180-token prompt behind it
    let rx_a = coordinator.submit("short warm prompt", 60, None).unwrap();
    let rx_b = coordinator.submit(&"y".repeat(180), 4, None).unwrap();
    assert!(rx_a.recv().unwrap().ok().is_ok());
    assert!(rx_b.recv().unwrap().ok().is_ok());
    let s = coordinator.stats().scheduler;
    assert_eq!(s.first_tokens, 2, "TTFT recorded per request");
    assert!(s.prefill_tokens >= 180 + 17);
    assert!(
        s.prefill_stall_tokens_max <= budget as u64,
        "stall {} > budget {budget}",
        s.prefill_stall_tokens_max
    );
    assert!(s.prefill_ticks >= (180 / budget) as u64);
    coordinator.shutdown();
}

#[test]
fn stats_command_reports_cluster_breakdown() {
    let (_c, server) = spawn_stack();
    let mut client = TcpClient::connect(server.addr()).unwrap();
    client.request("seed the counters please", 2, None).unwrap();
    let resp = client.stats().unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    let stats = resp.get("stats").expect("stats payload");
    assert_eq!(
        stats.get("num_workers").and_then(|v| v.as_usize()),
        Some(num_workers_from_env())
    );
    let agg = stats.get("aggregate").expect("aggregate block");
    assert!(agg.get("completed").and_then(|v| v.as_i64()).unwrap() >= 1);
    assert!(agg.get("hit_rate").and_then(|v| v.as_f64()).is_some());
    let workers = stats.get("workers").and_then(|v| v.as_arr()).expect("rows");
    assert_eq!(workers.len(), num_workers_from_env());
    // per-worker rows carry identity + queue depth alongside the counters
    assert_eq!(workers[0].get("worker").and_then(|v| v.as_usize()), Some(0));
    assert!(workers[0].get("queue_depth").is_some());
    // aggregate = sum of the per-worker rows (the merge law, over the wire)
    let sum: i64 = workers
        .iter()
        .map(|w| w.get("completed").and_then(|v| v.as_i64()).unwrap())
        .sum();
    assert_eq!(agg.get("completed").and_then(|v| v.as_i64()), Some(sum));
    server.stop();
}

#[test]
fn unknown_cmd_is_a_typed_error_not_a_disconnect() {
    let (_c, server) = spawn_stack();
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(b"{\"cmd\": \"selfdestruct\"}\n").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "bad reply: {line}");
    assert!(line.contains("selfdestruct"), "unhelpful message: {line}");
    // same connection still serves prompts
    w.write_all(br#"{"prompt": "still here", "max_new_tokens": 2}"#)
        .unwrap();
    w.write_all(b"\n").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "connection died: {line}");
    server.stop();
}

#[test]
fn four_worker_cluster_serves_over_tcp() {
    // Explicit N=4 regardless of the env knob: distinct prompt families
    // spread across workers, and the wire stats expose the breakdown.
    let (c, server) = spawn_stack_with(ServerConfig {
        num_workers: 4,
        ..Default::default()
    });
    let mut client = TcpClient::connect(server.addr()).unwrap();
    for i in 0..8 {
        let r = client
            .request(
                &format!("prompt family number {i} padded well past the fingerprint"),
                2,
                None,
            )
            .unwrap();
        assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true), "req {i}");
    }
    assert_eq!(c.stats().completed, 8);
    let resp = client.stats().unwrap();
    let stats = resp.get("stats").expect("stats payload");
    assert_eq!(stats.get("num_workers").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(
        stats.get("workers").and_then(|v| v.as_arr()).unwrap().len(),
        4
    );
    server.stop();
}

#[test]
fn stop_joins_idle_connection_threads() {
    // Regression, twice over: stop() once joined only the accept thread
    // (leaking a detached thread per connected client), then rode out a
    // 50ms per-connection read-timeout poll. The readiness-driven event
    // loop checks the shutdown flag every pass (1ms idle tick), so stop()
    // must return in single-digit milliseconds with a client still
    // connected and idle — asserted strictly under the old 50ms poll.
    let (_c, server) = spawn_stack();
    let idle = std::net::TcpStream::connect(server.addr()).unwrap();
    // give the accept loop a beat to register the connection
    std::thread::sleep(std::time::Duration::from_millis(30));
    let t0 = std::time::Instant::now();
    server.stop(); // would block forever on a leaked blocking read
    assert!(
        t0.elapsed() < std::time::Duration::from_millis(50),
        "stop() took {:?}: the shutdown path is polling, not readiness-driven",
        t0.elapsed()
    );
    drop(idle);
}

#[test]
fn multiple_clients_share_the_coordinator() {
    let (c, server) = spawn_stack();
    let addr = server.addr();
    let mut handles = Vec::new();
    for i in 0..3 {
        handles.push(std::thread::spawn(move || {
            let mut client = TcpClient::connect(addr).unwrap();
            let r = client
                .request(&format!("client {i} asking a question"), 2, None)
                .unwrap();
            assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(c.stats().completed >= 3);
    server.stop();
}
