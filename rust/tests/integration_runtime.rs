//! Integration tests over the REAL PJRT runtime and artifacts.
//!
//! These are the cross-language correctness gate: the Rust engine must
//! reproduce the Python-side golden fixtures (tokenizer ids, forward
//! logits, greedy generations, recycling equivalence) token-for-token.
//!
//! All tests skip (cleanly pass) when `artifacts/` is absent — run
//! `make artifacts` first.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use recycle_serve::engine::Engine;
use recycle_serve::kvcache::KvArena;
use recycle_serve::runtime::Runtime;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::json::{self, Value};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

fn fixtures(dir: &Path) -> Value {
    let text = std::fs::read_to_string(dir.join("fixtures.json")).unwrap();
    json::parse(&text).unwrap()
}

fn ids_of(v: &Value) -> Vec<u32> {
    v.as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap() as u32)
        .collect()
}

#[test]
fn runtime_loads_and_reports_config() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let cfg = rt.config();
    assert_eq!(cfg.name, "nano");
    assert_eq!(cfg.d_model, cfg.n_head * cfg.head_dim);
    assert!(!cfg.chunk_sizes.is_empty());
}

#[test]
fn tokenizer_matches_python_fixtures() {
    let dir = require_artifacts!();
    let tok = Tokenizer::from_file(&dir.join("tokenizer.json")).unwrap();
    let fx = fixtures(&dir);
    let mut checked = 0;
    for case in fx.req_arr("tokenizer").unwrap() {
        let text = case.req_str("text").unwrap();
        let want = ids_of(case.req("ids").unwrap());
        let got = tok.encode(text);
        assert_eq!(got, want, "text {text:?}");
        // decode roundtrip
        assert_eq!(tok.decode(&got), text, "decode {text:?}");
        checked += 1;
    }
    assert!(checked >= 10, "fixture set unexpectedly small");
}

#[test]
fn forward_logits_match_python_golden() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let fx = fixtures(&dir);
    let g = fx.req("forward_logits").unwrap();
    let prompt_ids = ids_of(g.req("prompt_ids").unwrap());
    let chunk = g.req_usize("chunk").unwrap();
    let cfg = rt.config().clone();

    let arena = KvArena::with_defaults(&cfg);
    let mut kv = arena.new_view();
    let mut padded = prompt_ids.clone();
    padded.resize(chunk, 0);
    use recycle_serve::engine::ForwardModel;
    let logits = rt
        .forward_chunk(&padded, prompt_ids.len(), &mut kv, 0)
        .unwrap();
    let v = cfg.vocab_size;
    let row = &logits[(prompt_ids.len() - 1) * v..prompt_ids.len() * v];

    let want_first8: Vec<f64> = g
        .req_arr("last_row_first8")
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    for (i, w) in want_first8.iter().enumerate() {
        assert!(
            (row[i] as f64 - w).abs() < 2e-3,
            "logit[{i}]: got {} want {w}",
            row[i]
        );
    }
    let argmax = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax, g.req_usize("last_row_argmax").unwrap());
}

#[test]
fn greedy_generation_matches_python_golden() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let fx = fixtures(&dir);
    let g = fx.req("greedy").unwrap();
    let prompt_ids = ids_of(g.req("prompt_ids").unwrap());
    let want = ids_of(g.req("generated_ids").unwrap());

    let mut engine = Engine::new(rt);
    let kv = engine.empty_kv();
    let out = engine.generate(&prompt_ids, kv, 0, 16, false).unwrap();
    assert_eq!(out.ids, want, "greedy tokens diverge from python");
    assert_eq!(out.final_len, g.req_usize("final_len").unwrap());
}

#[test]
fn recycling_equivalence_matches_python_golden() {
    // the paper's central claim, across the language boundary
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let fx = fixtures(&dir);
    let r = fx.req("recycle").unwrap();
    let cache_ids = ids_of(r.req("cache_ids").unwrap());
    let test_ids = ids_of(r.req("test_ids").unwrap());
    let want_base = ids_of(r.req("baseline_ids").unwrap());
    let depth = r.req_usize("reuse_depth").unwrap();
    assert_eq!(&test_ids[..depth], &cache_ids[..]);

    let mut engine = Engine::new(rt);

    // baseline
    let base = engine
        .generate(&test_ids, engine.empty_kv(), 0, 12, false)
        .unwrap();
    assert_eq!(base.ids, want_base, "baseline diverges from python");

    // build cache for the prefix, then recycle
    let mut kv = engine.empty_kv();
    engine.prefill(&cache_ids, &mut kv, 0).unwrap();
    let rec = engine.generate(&test_ids, kv, depth, 12, false).unwrap();
    assert_eq!(rec.ids, base.ids, "recycled != baseline");
    assert_eq!(rec.reused_tokens, depth);
}

#[test]
fn embed_matches_python_golden() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let fx = fixtures(&dir);
    let e = fx.req("embed").unwrap();
    let tok = rt.tokenizer();
    let ids = tok.encode(e.req_str("text").unwrap());
    let vec = rt.embedder().embed_tokens(&ids).unwrap();
    let want: Vec<f64> = e
        .req_arr("first8")
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    for (i, w) in want.iter().enumerate() {
        assert!((vec[i] as f64 - w).abs() < 1e-4, "embed[{i}]");
    }
    let norm: f32 = vec.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4);
}

#[test]
fn chunk_split_invariance_on_real_model() {
    // prefill in one big chunk vs many small chunks -> same logits
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let cfg = rt.config().clone();
    use recycle_serve::engine::ForwardModel;
    let v = cfg.vocab_size;
    let ids: Vec<u32> = (0..40u32).map(|i| 1 + (i * 7 + 3) % (v as u32 - 1)).collect();
    let arena = KvArena::with_defaults(&cfg);

    // one 64-chunk
    let mut kv1 = arena.new_view();
    let mut padded = ids.clone();
    padded.resize(64, 0);
    let l1 = rt.forward_chunk(&padded, ids.len(), &mut kv1, 0).unwrap();
    let row1 = &l1[(ids.len() - 1) * v..ids.len() * v];

    // 32 + 8 real rows of an 8-bucket
    let mut kv2 = arena.new_view();
    rt.forward_chunk(&ids[..32], 32, &mut kv2, 0).unwrap();
    let l2 = rt.forward_chunk(&ids[32..40], 8, &mut kv2, 32).unwrap();
    let row2 = &l2[7 * v..8 * v];

    for i in 0..v {
        assert!(
            (row1[i] - row2[i]).abs() < 1e-3,
            "logit {i}: {} vs {}",
            row1[i],
            row2[i]
        );
    }
    // KV views agree on the live region
    let [l, two, h, _s, d] = cfg.kv_shape();
    for li in 0..l {
        for t in 0..two {
            for hi in 0..h {
                for pos in 0..40 {
                    let a = kv1.row(li, t, hi, pos);
                    let b = kv2.row(li, t, hi, pos);
                    for x in 0..d {
                        assert!(
                            (a[x] - b[x]).abs() < 1e-4,
                            "kv[{li},{t},{hi},{pos},{x}]"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn context_exhaustion_is_an_error_not_corruption() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let cfg = rt.config().clone();
    use recycle_serve::engine::ForwardModel;
    let mut kv = KvArena::with_defaults(&cfg).new_view();
    let toks = vec![1u32; 64];
    let err = rt
        .forward_chunk(&toks, 64, &mut kv, cfg.max_seq - 10)
        .unwrap_err();
    assert!(matches!(
        err,
        recycle_serve::error::Error::ContextExhausted(_)
    ));
}

#[test]
fn full_recycler_stack_on_real_model() {
    use recycle_serve::config::CacheConfig;
    use recycle_serve::index::NgramEmbedder;
    use recycle_serve::recycler::{RecyclePolicy, Recycler};

    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let tok: Arc<Tokenizer> = rt.tokenizer();

    let mut rec = Recycler::new(
        Engine::new(rt),
        tok,
        Box::new(NgramEmbedder::new(128)),
        CacheConfig::default(),
        RecyclePolicy::Strict,
    );
    rec.warm(&["What is the capital of France?"]).unwrap();
    let hit = rec
        .generate(
            "What is the capital of France? Also mention a nearby tourist destination.",
            16,
        )
        .unwrap();
    assert!(hit.cache_hit);
    assert!(hit.reuse_depth >= 5);

    // and equivalence against a fresh baseline
    let rt2 = Runtime::load(&dir).unwrap();
    let tok2 = rt2.tokenizer();
    let mut base = Recycler::new(
        Engine::new(rt2),
        tok2,
        Box::new(NgramEmbedder::new(128)),
        CacheConfig::default(),
        RecyclePolicy::Off,
    );
    let b = base
        .generate(
            "What is the capital of France? Also mention a nearby tourist destination.",
            16,
        )
        .unwrap();
    assert_eq!(hit.ids, b.ids, "recycled generation must equal baseline");
    assert_eq!(hit.text, b.text);
}
